//! Online per-feature standardization.
//!
//! Raw vSphere counters mix units (ms of CPU Ready, %, KB/s, counts), so an
//! unscaled PCA is dominated by the largest-magnitude features. Every
//! practical PCA pipeline scales features first; in a streaming setting the
//! natural choice is a running Welford mean/variance per feature with
//! z-scaling — O(d) state, one pass, no look-ahead. [`NodeScheduler`]
//! applies this by default ahead of the embedding.
//!
//! [`NodeScheduler`]: super::NodeScheduler

/// Streaming per-feature z-scaler.
#[derive(Debug, Clone)]
pub struct OnlineStandardizer {
    n: f64,
    mean: Vec<f64>,
    m2: Vec<f64>,
    /// Scratch output buffer.
    out: Vec<f64>,
}

impl OnlineStandardizer {
    pub fn new(dim: usize) -> Self {
        Self { n: 0.0, mean: vec![0.0; dim], m2: vec![0.0; dim], out: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Observations consumed.
    pub fn count(&self) -> u64 {
        self.n as u64
    }

    /// Update the running moments with `y` and return the standardized
    /// vector (borrowed scratch — copy if it must outlive the next call).
    ///
    /// Failure injection: real telemetry exporters emit NaN/∞ on counter
    /// wrap or agent restart. Non-finite inputs are treated as "no signal"
    /// — they do not update the moments and standardize to 0, so one bad
    /// export can never poison the filter state or the embedding.
    pub fn transform(&mut self, y: &[f64]) -> &[f64] {
        assert_eq!(y.len(), self.mean.len());
        self.n += 1.0;
        for i in 0..y.len() {
            if !y[i].is_finite() {
                self.out[i] = 0.0;
                continue;
            }
            let delta = y[i] - self.mean[i];
            self.mean[i] += delta / self.n;
            self.m2[i] += delta * (y[i] - self.mean[i]);
            let std = (self.m2[i] / self.n).sqrt();
            self.out[i] = if std > 1e-12 { (y[i] - self.mean[i]) / std } else { 0.0 };
        }
        &self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn standardized_stream_has_unit_scale() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut st = OnlineStandardizer::new(3);
        let mut sums = [0.0f64; 3];
        let mut sq = [0.0f64; 3];
        let n = 20_000;
        for _ in 0..n {
            let y = [
                1000.0 + 50.0 * rng.normal(),
                rng.normal() * 0.001,
                5.0,
            ];
            let z = st.transform(&y);
            for i in 0..3 {
                sums[i] += z[i];
                sq[i] += z[i] * z[i];
            }
        }
        for i in 0..2 {
            let mean = sums[i] / n as f64;
            let var = sq[i] / n as f64;
            assert!(mean.abs() < 0.05, "feature {i} mean {mean}");
            assert!((var - 1.0).abs() < 0.1, "feature {i} var {var}");
        }
        // Constant feature maps to exactly zero.
        assert_eq!(sq[2], 0.0);
    }

    #[test]
    fn non_finite_inputs_are_neutralized() {
        let mut st = OnlineStandardizer::new(2);
        for i in 0..50 {
            st.transform(&[i as f64, 1.0]);
        }
        let z = st.transform(&[f64::NAN, f64::INFINITY]);
        assert_eq!(z, &[0.0, 0.0]);
        // Moments unpoisoned: next clean sample standardizes finitely.
        let z = st.transform(&[25.0, 1.0]);
        assert!(z.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn first_observation_is_zero() {
        let mut st = OnlineStandardizer::new(2);
        let z = st.transform(&[7.0, -3.0]);
        assert_eq!(z, &[0.0, 0.0]);
    }
}
