//! Configuration system: a TOML-subset parser + the typed [`ProntoConfig`].
//!
//! No serde in this environment, so we parse the practical subset of TOML
//! the configs need: `[section]` headers, `key = value` with strings,
//! numbers, booleans, and flat arrays. Unknown keys are rejected (typos
//! should fail loudly at startup, not silently default).

mod toml;

pub use toml::{parse_toml, TomlValue};

use crate::detect::ZScoreConfig;
use crate::federation::LatencyModel;
use crate::fpca::FpcaEdgeConfig;
use crate::scheduler::RejectConfig;
use crate::sim::SimConfig;
use crate::telemetry::GeneratorConfig;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Top-level runtime configuration for the `pronto` binary.
#[derive(Debug, Clone)]
pub struct ProntoConfig {
    /// Number of data-center nodes.
    pub nodes: usize,
    /// Trace length in 20 s timesteps.
    pub steps: usize,
    /// Federation fanout.
    pub fanout: usize,
    /// ε threshold of the upward-merge gate.
    pub epsilon: f64,
    /// Master seed.
    pub seed: u64,
    /// Default scenario for `pronto sim` (catalog name or TOML path);
    /// `--scenario` on the command line overrides it.
    pub scenario: Option<String>,
    /// Leaves offer their iterate every this many observations
    /// (federation runtimes).
    pub push_every: usize,
    /// Simulated federation push delivery latency.
    pub push_latency: LatencyModel,
    pub generator: GeneratorConfig,
    pub fpca: FpcaEdgeConfig,
    pub reject: RejectConfig,
    pub sim: SimConfig,
}

impl Default for ProntoConfig {
    fn default() -> Self {
        Self {
            nodes: 16,
            steps: 4_000,
            fanout: 8,
            epsilon: 0.5,
            seed: 2021,
            scenario: None,
            push_every: 64,
            push_latency: LatencyModel::None,
            generator: GeneratorConfig::default(),
            fpca: FpcaEdgeConfig::default(),
            reject: RejectConfig::default(),
            sim: SimConfig::default(),
        }
    }
}

impl ProntoConfig {
    /// Load from a TOML file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse from TOML text. Every key is optional; sections:
    /// `[pronto]`, `[generator]`, `[fpca]`, `[reject]`, `[sim]`.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = parse_toml(text).map_err(|e| anyhow::anyhow!("config: {e}"))?;
        let mut cfg = Self::default();
        for (section, entries) in &doc {
            for (key, value) in entries {
                cfg.apply(section, key, value)?;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    fn apply(&mut self, section: &str, key: &str, v: &TomlValue) -> Result<()> {
        let num = || -> Result<f64> {
            v.as_f64().ok_or_else(|| anyhow::anyhow!("{section}.{key}: expected number"))
        };
        let uint = || -> Result<usize> { Ok(num()? as usize) };
        let boolean = || -> Result<bool> {
            v.as_bool().ok_or_else(|| anyhow::anyhow!("{section}.{key}: expected bool"))
        };
        match (section, key) {
            ("pronto", "nodes") => self.nodes = uint()?,
            ("pronto", "steps") => self.steps = uint()?,
            ("pronto", "fanout") => self.fanout = uint()?,
            ("pronto", "epsilon") => self.epsilon = num()?,
            ("pronto", "seed") => self.seed = num()? as u64,
            ("pronto", "scenario") => {
                self.scenario = Some(
                    v.as_str()
                        .ok_or_else(|| anyhow::anyhow!("pronto.scenario: expected string"))?
                        .to_string(),
                )
            }

            // Federation link knobs. The latency keys each fully select a
            // model (last one wins); the richer matrix lives in scenario
            // TOML ([federation] there supports uniform bounds too).
            ("federation", "push_every") => self.push_every = uint()?,
            ("federation", "latency_constant_steps") => {
                self.push_latency = LatencyModel::Constant { steps: num()? }
            }
            ("federation", "latency_mean_steps") => {
                self.push_latency = LatencyModel::Exponential { mean_steps: num()? }
            }

            ("generator", "ready_mu_ms") => self.generator.ready_mu = num()?.ln(),
            ("generator", "ready_sigma") => self.generator.ready_sigma = num()?,
            ("generator", "episode_hazard") => self.generator.episode_hazard = num()?,
            ("generator", "hazard_load_gain") => self.generator.hazard_load_gain = num()?,
            ("generator", "lead") => self.generator.lead = uint()?,
            ("generator", "mean_episode_len") => self.generator.mean_episode_len = num()?,
            ("generator", "precursor_gain") => self.generator.precursor_gain = num()?,
            ("generator", "surprise_rate") => self.generator.surprise_rate = num()?,
            ("generator", "obs_noise") => self.generator.obs_noise = num()?,
            ("generator", "ar_rho") => self.generator.ar_rho = num()?,

            ("fpca", "initial_rank") => self.fpca.initial_rank = uint()?,
            ("fpca", "max_rank") => self.fpca.max_rank = uint()?,
            ("fpca", "min_rank") => self.fpca.min_rank = uint()?,
            ("fpca", "block_size") => self.fpca.block_size = uint()?,
            ("fpca", "forget") => self.fpca.forget = num()?,
            ("fpca", "adaptive_rank") => self.fpca.adaptive_rank = boolean()?,
            ("fpca", "energy_alpha") => self.fpca.energy.alpha = num()?,
            ("fpca", "energy_beta") => self.fpca.energy.beta = num()?,

            ("reject", "lag") => self.reject.zscore.lag = uint()?,
            ("reject", "alpha") => self.reject.zscore.alpha = num()?,
            ("reject", "beta") => self.reject.zscore.beta = num()?,
            ("reject", "threshold") => self.reject.threshold = num()?,
            ("reject", "max_rank") => self.reject.max_rank = uint()?,
            ("reject", "normalize_sigma") => self.reject.normalize_sigma = boolean()?,
            ("reject", "signed_flags") => self.reject.signed_flags = boolean()?,

            ("sim", "arrival_rate_per_step") => self.sim.arrival_rate_per_step = num()?,
            ("sim", "duration_mu") => self.sim.duration_mu = num()?,
            ("sim", "duration_sigma") => self.sim.duration_sigma = num()?,
            ("sim", "ready_threshold") => self.sim.ready_threshold = num()?,
            ("sim", "score_window") => self.sim.score_window = uint()?,
            ("sim", "seed") => self.sim.seed = num()? as u64,

            _ => bail!("unknown config key [{section}] {key}"),
        }
        Ok(())
    }

    fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.steps == 0 {
            bail!("nodes and steps must be positive");
        }
        if self.fanout < 2 {
            bail!("fanout must be >= 2");
        }
        if self.push_every == 0 {
            bail!("federation.push_every must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.generator.surprise_rate) {
            bail!("generator.surprise_rate must be in [0, 1]");
        }
        if self.fpca.min_rank > self.fpca.max_rank
            || self.fpca.initial_rank > self.fpca.max_rank
        {
            bail!("fpca rank bounds inconsistent");
        }
        let z: &ZScoreConfig = &self.reject.zscore;
        if z.lag < 2 || z.alpha <= 0.0 || !(0.0..=1.0).contains(&z.beta) {
            bail!("reject.zscore parameters out of range");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        assert!(ProntoConfig::default().validate().is_ok());
    }

    #[test]
    fn parses_full_config() {
        let cfg = ProntoConfig::parse(
            r#"
[pronto]
nodes = 32
steps = 1000
fanout = 4
epsilon = 0.25
seed = 7

[generator]
ready_sigma = 0.9
lead = 4

[fpca]
initial_rank = 3
block_size = 16
adaptive_rank = true

[reject]
alpha = 3.0
threshold = 0.8
signed_flags = true

[federation]
push_every = 32
latency_mean_steps = 6.0

[sim]
arrival_rate_per_step = 0.5
"#,
        )
        .unwrap();
        assert_eq!(cfg.nodes, 32);
        assert_eq!(cfg.push_every, 32);
        assert_eq!(
            cfg.push_latency,
            crate::federation::LatencyModel::Exponential { mean_steps: 6.0 }
        );
        assert_eq!(cfg.fanout, 4);
        assert_eq!(cfg.generator.lead, 4);
        assert_eq!(cfg.fpca.initial_rank, 3);
        assert!(cfg.fpca.adaptive_rank);
        assert_eq!(cfg.reject.zscore.alpha, 3.0);
        assert!(cfg.reject.signed_flags);
        assert_eq!(cfg.sim.arrival_rate_per_step, 0.5);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ProntoConfig::parse("[pronto]\nnodez = 3\n").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(ProntoConfig::parse("[pronto]\nfanout = 1\n").is_err());
        assert!(ProntoConfig::parse("[generator]\nsurprise_rate = 2.0\n").is_err());
        assert!(ProntoConfig::parse("[reject]\nlag = 1\n").is_err());
    }
}
