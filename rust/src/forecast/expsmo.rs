//! Exponential smoothing (§3.1 method 2): the forecast is the smoothed
//! average of the window, newest values weighted most. The paper reports
//! best results at α = 0.2.

use super::{with_normalization, Forecaster};

/// Simple exponential smoothing forecaster.
#[derive(Debug, Clone, Copy)]
pub struct ExpSmoothing {
    pub alpha: f64,
}

impl Default for ExpSmoothing {
    fn default() -> Self {
        Self { alpha: 0.2 }
    }
}

impl ExpSmoothing {
    /// The smoothed level after consuming the whole series.
    pub fn level(&self, xs: &[f64]) -> f64 {
        assert!(!xs.is_empty());
        let mut level = xs[0];
        for &x in &xs[1..] {
            level = self.alpha * x + (1.0 - self.alpha) * level;
        }
        level
    }
}

impl Forecaster for ExpSmoothing {
    fn name(&self) -> &'static str {
        "ExpSmo"
    }

    fn forecast(&self, history: &[f64], _pool: &[&[f64]], horizon: usize) -> Vec<f64> {
        with_normalization(history, |scaled| vec![self.level(scaled); horizon])
    }

    fn forecast_rolling(&self, history: &[f64], _pool: &[&[f64]], future: &[f64]) -> Vec<f64> {
        // Maintain the smoothed level over the revealed actuals (raw scale:
        // smoothing is shift/scale-equivariant, so normalization is a
        // no-op here).
        let mut level = self.level(history);
        future
            .iter()
            .map(|&actual| {
                let pred = level;
                level = self.alpha * actual + (1.0 - self.alpha) * level;
                pred
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_series_predicts_constant() {
        let f = ExpSmoothing::default();
        let out = f.forecast(&[5.0; 20], &[], 3);
        for v in out {
            assert!((v - 5.0).abs() < 1e-9);
        }
    }

    #[test]
    fn level_weights_recent_values() {
        let f = ExpSmoothing { alpha: 0.5 };
        // Step from 0 to 10: level should sit between but closer to 10
        // after several 10s.
        let lvl = f.level(&[0.0, 10.0, 10.0, 10.0]);
        assert!(lvl > 8.0 && lvl < 10.0, "lvl={lvl}");
    }

    #[test]
    fn smoother_tracks_trend_slower_with_small_alpha() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let fast = ExpSmoothing { alpha: 0.8 }.level(&xs);
        let slow = ExpSmoothing { alpha: 0.1 }.level(&xs);
        assert!(fast > slow);
    }
}
