//! `pronto bench diff OLD.json NEW.json [--max-regress PCT]` — the perf
//! regression gate over two `BENCH_engine.json` artifacts.
//!
//! Rows are joined by `(scenario, nodes, threads)` (fleet size and
//! observe-loop width are part of a measurement's identity; `threads`
//! defaults to 1 when absent so schema-v1 artifacts still diff) and the
//! per-row `events_per_sec` figures are compared. A row whose throughput
//! dropped by more than the threshold is a **regression**; the CLI exits
//! non-zero when any exists, which is what lets CI (and local
//! pre-submit) gate a PR on the engine's perf trajectory:
//!
//! ```text
//! pronto bench engine --out BENCH_new.json
//! pronto bench diff BENCH_baseline.json BENCH_new.json --max-regress 10
//! ```
//!
//! Rows present on only one side are printed with the joined rows —
//! old-only as `dropped`, new-only as `new` rows with their measured
//! throughput — but never fail the gate by default: sweeps legitimately
//! grow and shrink across PRs. `--require-baseline` flips that for
//! new-only rows, failing the run until the baseline artifact is
//! regenerated (the strict mode CI uses once a sweep's shape is
//! pinned). Both `BENCH_engine.json` (`bench = "engine"`) and
//! `SWEEP_*.json` (`bench = "sweep"`) artifacts diff; grid rows join by
//! their composite `scenario` id. Wall-clock noise is the caller's
//! problem: compare artifacts from the same machine and pick a
//! threshold wide enough for its variance (the README documents the
//! workflow).

use crate::ser::{parse_json, JsonValue};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Identity of one bench row: the join key of the diff.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct RowKey {
    pub scenario: String,
    pub nodes: usize,
    pub threads: usize,
}

impl std::fmt::Display for RowKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} @ {} nodes x {} threads", self.scenario, self.nodes, self.threads)
    }
}

/// One joined row: old and new throughput plus the relative change.
#[derive(Debug, Clone)]
pub struct RowDiff {
    pub key: RowKey,
    pub old_events_per_sec: f64,
    pub new_events_per_sec: f64,
    /// `(new − old) / old × 100`; negative = slower. `None` when the
    /// baseline throughput is zero — a relative change has no anchor
    /// there (the naive division yields `inf`/`NaN`), so such rows
    /// render as `n/a` and never trip the regression gate.
    pub delta_pct: Option<f64>,
}

/// The full comparison of two artifacts.
#[derive(Debug, Clone, Default)]
pub struct BenchDiff {
    /// Joined rows, in key order.
    pub rows: Vec<RowDiff>,
    /// Rows only the old artifact has (dropped from the sweep).
    pub only_old: Vec<RowKey>,
    /// Rows only the new artifact has (new sweep entries), with their
    /// measured throughput. These were once dropped from the report
    /// entirely — a fresh sweep/scale row could silently never gate —
    /// so they now render as explicit `new` rows, and strict callers
    /// (`--require-baseline`) can refuse them outright.
    pub only_new: Vec<(RowKey, f64)>,
}

impl BenchDiff {
    /// Joined rows slower by more than `max_regress_pct` percent.
    /// Zero-baseline rows (`delta_pct == None`) are skipped: with no
    /// anchor there is no percentage to compare against the threshold.
    pub fn regressions_beyond(&self, max_regress_pct: f64) -> Vec<&RowDiff> {
        self.rows
            .iter()
            .filter(|r| matches!(r.delta_pct, Some(d) if d < -max_regress_pct))
            .collect()
    }

    /// Largest throughput drop across joined rows, as a positive percent
    /// (0 when nothing got slower; zero-baseline rows are skipped).
    pub fn worst_regression_pct(&self) -> f64 {
        self.rows.iter().filter_map(|r| r.delta_pct.map(|d| -d)).fold(0.0, f64::max)
    }

    /// Human-readable table (one line per joined row, then the
    /// unmatched-row notes).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<44} {:>14} {:>14} {:>9}\n",
            "row (scenario @ nodes x threads)", "old ev/s", "new ev/s", "delta"
        ));
        for r in &self.rows {
            // Pre-render the key: width/fill specs only apply to `&str`
            // (a custom `Display` ignores the padding).
            let key = r.key.to_string();
            let delta = match r.delta_pct {
                Some(d) => format!("{d:>+8.1}%"),
                None => format!("{:>9}", "n/a"),
            };
            out.push_str(&format!(
                "{key:<44} {:>14.0} {:>14.0} {delta}\n",
                r.old_events_per_sec, r.new_events_per_sec
            ));
        }
        for (k, eps) in &self.only_new {
            // New rows line up under the same columns: no baseline
            // figure, the measured throughput, and `new` in the delta
            // slot so the eye catches them next to real regressions.
            let key = k.to_string();
            out.push_str(&format!(
                "{key:<44} {:>14} {eps:>14.0} {:>9}\n",
                "-", "new"
            ));
        }
        for k in &self.only_old {
            let key = k.to_string();
            out.push_str(&format!("{key:<44} dropped from the new sweep\n"));
        }
        out
    }
}

/// Extract `(key → events_per_sec)` from one benchmark artifact —
/// `BENCH_engine.json` (`runs` array) or `SWEEP_*.json` (`rows` array;
/// each row's `scenario` is its composite grid id). Validates the
/// artifact kind and rejects duplicate keys — a doubled row means the
/// join would silently compare the wrong pair.
pub fn parse_bench_rows(text: &str, label: &str) -> Result<BTreeMap<RowKey, f64>> {
    let doc = parse_json(text).map_err(|e| anyhow!("{label}: invalid JSON: {e}"))?;
    let rows_key = match doc.get("bench").and_then(JsonValue::as_str) {
        Some("engine") => "runs",
        Some("sweep") => "rows",
        other => bail!("{label}: not a bench artifact (bench = {other:?})"),
    };
    let runs = doc
        .get(rows_key)
        .and_then(JsonValue::as_array)
        .ok_or_else(|| anyhow!("{label}: missing {rows_key} array"))?;
    let mut rows = BTreeMap::new();
    for (i, run) in runs.iter().enumerate() {
        let scenario = run
            .get("scenario")
            .and_then(JsonValue::as_str)
            .ok_or_else(|| anyhow!("{label}: run {i} missing scenario"))?
            .to_string();
        let nodes = run
            .get("nodes")
            .and_then(JsonValue::as_usize)
            .ok_or_else(|| anyhow!("{label}: run {i} missing nodes"))?;
        // Absent on schema-v1 artifacts, which were all sequential.
        let threads = run.get("threads").and_then(JsonValue::as_usize).unwrap_or(1);
        let eps = run
            .get("events_per_sec")
            .and_then(JsonValue::as_f64)
            .ok_or_else(|| anyhow!("{label}: run {i} missing events_per_sec"))?;
        // Zero is a legal measurement (a row whose run dispatched nothing
        // still identifies itself); negative or non-finite throughput is
        // a corrupt artifact.
        if !(eps.is_finite() && eps >= 0.0) {
            bail!("{label}: run {i} has an invalid events_per_sec ({eps})");
        }
        let key = RowKey { scenario, nodes, threads };
        if rows.insert(key.clone(), eps).is_some() {
            bail!("{label}: duplicate bench row {key}");
        }
    }
    Ok(rows)
}

/// Join two artifacts' rows and compute per-row throughput deltas.
pub fn bench_diff(old_text: &str, new_text: &str) -> Result<BenchDiff> {
    let old = parse_bench_rows(old_text, "old artifact")?;
    let mut new = parse_bench_rows(new_text, "new artifact")?;
    let mut diff = BenchDiff::default();
    for (key, old_eps) in old {
        match new.remove(&key) {
            Some(new_eps) => diff.rows.push(RowDiff {
                key,
                old_events_per_sec: old_eps,
                new_events_per_sec: new_eps,
                delta_pct: (old_eps > 0.0).then(|| (new_eps - old_eps) / old_eps * 100.0),
            }),
            None => diff.only_old.push(key),
        }
    }
    diff.only_new.extend(new);
    if diff.rows.is_empty() {
        bail!(
            "no comparable rows: the artifacts share no (scenario, nodes, threads) key \
             ({} old-only, {} new-only)",
            diff.only_old.len(),
            diff.only_new.len()
        );
    }
    Ok(diff)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(rows: &[(&str, usize, usize, f64)]) -> String {
        let runs: Vec<String> = rows
            .iter()
            .map(|(s, n, t, eps)| {
                format!(
                    r#"{{"scenario":"{s}","nodes":{n},"threads":{t},"events_per_sec":{eps},"events":1000,"wall_ms":5.0}}"#
                )
            })
            .collect();
        format!(
            r#"{{"bench":"engine","schema_version":2,"runs":[{}]}}"#,
            runs.join(",")
        )
    }

    #[test]
    fn synthetic_regression_beyond_threshold_is_flagged() {
        // The acceptance fixture: one row drops 15 % — past a 10 % gate,
        // inside a 20 % one.
        let old = doc(&[("large-fleet", 1000, 1, 100_000.0), ("capacity", 50, 1, 40_000.0)]);
        let new = doc(&[("large-fleet", 1000, 1, 85_000.0), ("capacity", 50, 1, 44_000.0)]);
        let d = bench_diff(&old, &new).unwrap();
        assert_eq!(d.rows.len(), 2);
        let bad = d.regressions_beyond(10.0);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].key.scenario, "large-fleet");
        assert!((bad[0].delta_pct.unwrap() - (-15.0)).abs() < 1e-9);
        assert!(d.regressions_beyond(20.0).is_empty());
        assert!((d.worst_regression_pct() - 15.0).abs() < 1e-9);
        let table = d.render();
        assert!(table.contains("large-fleet"));
        assert!(table.contains("-15.0%"));
    }

    #[test]
    fn improvements_never_regress_and_rows_join_by_full_key() {
        let old = doc(&[("bursty", 100, 1, 50_000.0), ("bursty", 100, 4, 120_000.0)]);
        let new = doc(&[("bursty", 100, 1, 55_000.0), ("bursty", 100, 4, 130_000.0)]);
        let d = bench_diff(&old, &new).unwrap();
        assert_eq!(d.rows.len(), 2, "thread widths must join separately");
        assert!(d.regressions_beyond(0.0).is_empty());
        assert_eq!(d.worst_regression_pct(), 0.0);
    }

    #[test]
    fn unmatched_rows_are_reported_not_fatal() {
        let old = doc(&[("capacity", 50, 1, 10_000.0), ("gone", 8, 1, 5_000.0)]);
        let new = doc(&[("capacity", 50, 1, 10_500.0), ("fresh", 9, 1, 7_000.0)]);
        let d = bench_diff(&old, &new).unwrap();
        assert_eq!(d.rows.len(), 1);
        assert_eq!(d.only_old.len(), 1);
        // New-only rows keep their measured throughput and render as
        // explicit `new` table rows — before this fix they were reduced
        // to their key and a footnote, so a fresh sweep row never
        // surfaced its first measurement.
        assert_eq!(d.only_new.len(), 1);
        assert_eq!(d.only_new[0].0.scenario, "fresh");
        assert!((d.only_new[0].1 - 7_000.0).abs() < 1e-9);
        let table = d.render();
        assert!(table.contains("dropped from the new sweep"));
        let fresh_line = table
            .lines()
            .find(|l| l.contains("fresh"))
            .expect("new-only row must render");
        assert!(fresh_line.contains("7000"), "{fresh_line}");
        assert!(fresh_line.trim_end().ends_with("new"), "{fresh_line}");
    }

    #[test]
    fn sweep_artifacts_diff_by_composite_grid_id() {
        let mk = |eps_a: f64, eps_b: f64| {
            format!(
                concat!(
                    r#"{{"bench":"sweep","schema_version":1,"rows":["#,
                    r#"{{"scenario":"sweep/queue-aware/f0.0020","nodes":24,"threads":1,"events_per_sec":{}}},"#,
                    r#"{{"scenario":"sweep/signal-only/f0.0000","nodes":24,"threads":1,"events_per_sec":{}}}"#,
                    r#"]}}"#
                ),
                eps_a, eps_b
            )
        };
        let d = bench_diff(&mk(50_000.0, 60_000.0), &mk(40_000.0, 61_000.0)).unwrap();
        assert_eq!(d.rows.len(), 2);
        let bad = d.regressions_beyond(10.0);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].key.scenario, "sweep/queue-aware/f0.0020");
        // Engine and sweep artifacts never share keys, so cross-kind
        // diffs fail the no-comparable-rows check instead of silently
        // comparing unrelated measurements.
        let engine = doc(&[("capacity", 50, 1, 1.0)]);
        assert!(bench_diff(&engine, &mk(1.0, 1.0)).is_err());
    }

    #[test]
    fn v1_artifacts_without_threads_default_to_width_one() {
        let old = r#"{"bench":"engine","schema_version":1,"runs":[{"scenario":"capacity","nodes":50,"events_per_sec":9000.0}]}"#;
        let new = doc(&[("capacity", 50, 1, 9100.0)]);
        let d = bench_diff(old, &new).unwrap();
        assert_eq!(d.rows.len(), 1);
        assert_eq!(d.rows[0].key.threads, 1);
    }

    #[test]
    fn malformed_artifacts_are_typed_errors() {
        assert!(bench_diff("not json", "{}").is_err());
        // Wrong artifact kind.
        assert!(bench_diff(r#"{"bench":"tables","runs":[]}"#, "{}").is_err());
        // Duplicate key within one artifact.
        let dup = doc(&[("capacity", 50, 1, 1.0), ("capacity", 50, 1, 2.0)]);
        let ok = doc(&[("capacity", 50, 1, 1.0)]);
        assert!(bench_diff(&dup, &ok).is_err());
        // Disjoint sweeps: nothing comparable.
        let a = doc(&[("capacity", 50, 1, 1.0)]);
        let b = doc(&[("bursty", 10, 1, 1.0)]);
        assert!(bench_diff(&a, &b).is_err());
        // Negative or non-finite throughput is a corrupt artifact.
        let neg = doc(&[("capacity", 50, 1, -3.0)]);
        assert!(bench_diff(&neg, &ok).is_err());
        let nan = r#"{"bench":"engine","runs":[{"scenario":"capacity","nodes":50,"threads":1,"events_per_sec":1e999}]}"#;
        assert!(bench_diff(nan, &ok).is_err());
    }

    #[test]
    fn zero_baseline_rows_render_na_and_never_gate() {
        // A baseline row can legitimately record 0 events/s (e.g. a
        // placeholder row added before the first real measurement, or a
        // degenerate smoke run). The percent change has no anchor, so
        // the row must neither divide to inf/NaN nor trip the gate —
        // before this fix the parser rejected the whole artifact.
        let old = doc(&[("large-fleet", 100_000, 4, 0.0), ("capacity", 50, 1, 40_000.0)]);
        let new = doc(&[("large-fleet", 100_000, 4, 90_000.0), ("capacity", 50, 1, 41_000.0)]);
        let d = bench_diff(&old, &new).unwrap();
        assert_eq!(d.rows.len(), 2);
        let zero_row = d.rows.iter().find(|r| r.key.scenario == "large-fleet").unwrap();
        assert_eq!(zero_row.delta_pct, None);
        assert!(d.regressions_beyond(0.0).is_empty(), "n/a rows never regress");
        assert_eq!(d.worst_regression_pct(), 0.0);
        let table = d.render();
        assert!(table.contains("n/a"), "zero-baseline delta renders as n/a:\n{table}");
        assert!(!table.contains("inf") && !table.contains("NaN"), "{table}");
        // The degenerate direction too: both sides zero, and a new-side
        // zero against a real baseline (that one *is* a -100% regression).
        let both = bench_diff(&old, &old).unwrap();
        assert!(both.regressions_beyond(0.0).is_empty());
        let collapsed = bench_diff(&new, &old).unwrap();
        let bad = collapsed.regressions_beyond(50.0);
        assert_eq!(bad.len(), 1);
        assert!((bad[0].delta_pct.unwrap() - (-100.0)).abs() < 1e-9);
    }
}
