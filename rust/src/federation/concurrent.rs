//! Thread-per-leaf concurrent federation runtime.
//!
//! Exercises the same merge semantics as [`super::FederationTree`] under
//! real parallelism: each leaf runs its full local pipeline (embedding +
//! Reject-Job) over its own telemetry shard on a dedicated thread and
//! pushes ε-gated iterates over a channel; aggregator threads merge
//! summaries and forward upward once (DASM). This is the engine behind the
//! horizontal-scalability bench (§1: "in the absence of communication
//! latency, it exhibits attractive horizontal scalability").

use super::latency::LatencyModel;
use super::tree::TreeTopology;
use crate::fpca::{merge_subspaces, MergeOptions, Subspace};
use crate::rng::Xoshiro256;
use crate::scheduler::{NodeScheduler, RejectConfig};
use crate::telemetry::VmTrace;
use std::sync::mpsc;
use std::thread;

/// Message sent up the tree: a leaf/aggregator summary.
struct Summary {
    subspace: Subspace,
}

/// Outcome of a concurrent federation run.
#[derive(Debug)]
pub struct FederationReport {
    /// Number of leaves.
    pub leaves: usize,
    /// Telemetry steps processed per leaf.
    pub steps_per_leaf: usize,
    /// Iterate pushes that reached an aggregator.
    pub pushes: usize,
    /// Pushes suppressed by the ε gate.
    pub suppressed: usize,
    /// Pushes whose simulated delivery fell past the end of the run
    /// (dropped — only nonzero under a latency model).
    pub late_drops: usize,
    /// Total timesteps with the rejection signal raised, summed over leaves.
    pub rejected_steps: usize,
    /// The merged global view at the root.
    pub global_view: Subspace,
    /// Wall-clock duration of the whole run, as stamped by the *caller*
    /// via [`FederationReport::with_wall`]. [`ConcurrentFederation::run`]
    /// itself never reads the clock — the deterministic federation path
    /// is wall-clock-free (`pronto lint` enforces this) — so this is
    /// `Duration::ZERO` unless a timing-permitted caller (bench/CLI)
    /// stamps it.
    pub wall: std::time::Duration,
}

impl FederationReport {
    /// Stamp the caller-measured wall-clock duration onto the report.
    /// Timing lives with callers in `bench`/`cli`, where wall-clock
    /// reads are permitted; the federation run itself stays
    /// deterministic.
    pub fn with_wall(mut self, wall: std::time::Duration) -> Self {
        self.wall = wall;
        self
    }

    /// Aggregate throughput in observations/second. Meaningful only
    /// after [`Self::with_wall`]; with the default zero duration the
    /// `1e-9` floor makes this a large-but-finite placeholder.
    pub fn throughput(&self) -> f64 {
        (self.leaves * self.steps_per_leaf) as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Concurrent federation driver.
pub struct ConcurrentFederation {
    topo: TreeTopology,
    rank: usize,
    epsilon: f64,
    reject_cfg: RejectConfig,
    /// Push the local iterate every `push_every` observations.
    push_every: usize,
    /// Simulated push delivery latency (in observation steps).
    latency: LatencyModel,
    /// Seed for the per-leaf latency RNG streams.
    latency_seed: u64,
}

impl ConcurrentFederation {
    pub fn new(topo: TreeTopology, rank: usize, epsilon: f64) -> Self {
        Self {
            topo,
            rank,
            epsilon,
            reject_cfg: RejectConfig::default(),
            push_every: 64,
            latency: LatencyModel::None,
            latency_seed: 0x1ee7,
        }
    }

    pub fn with_push_every(mut self, every: usize) -> Self {
        assert!(every >= 1);
        self.push_every = every;
        self
    }

    /// Delay each leaf's pushes by a sampled number of observation steps:
    /// the leaf holds the **snapshot taken at send time** and delivers it
    /// once its delivery step passes — aggregators merge stale iterates,
    /// as they would across a real WAN. Pushes that would deliver after
    /// the run ends are dropped and counted in
    /// [`FederationReport::late_drops`].
    pub fn with_latency(mut self, latency: LatencyModel, seed: u64) -> Self {
        self.latency = latency;
        self.latency_seed = seed;
        self
    }

    /// Run the federation over per-leaf traces (one [`VmTrace`] per leaf).
    /// Spawns one thread per leaf plus one per aggregator group and a root
    /// merger; joins everything before returning.
    pub fn run(&self, traces: Vec<VmTrace>) -> FederationReport {
        assert_eq!(traces.len(), self.topo.leaves, "one trace per leaf");
        assert!(!traces.is_empty());
        let steps_per_leaf = traces.iter().map(|t| t.len()).min().unwrap_or(0);
        let fanout = self.topo.fanout;
        let groups = self.topo.leaves.div_ceil(fanout);

        // Channels: leaves → their group aggregator; aggregators → root.
        let (root_tx, root_rx) = mpsc::channel::<Summary>();
        let mut group_txs = Vec::with_capacity(groups);
        let mut agg_handles = Vec::with_capacity(groups);
        for _ in 0..groups {
            let (tx, rx) = mpsc::channel::<Summary>();
            group_txs.push(tx);
            let root_tx = root_tx.clone();
            let rank = self.rank;
            agg_handles.push(thread::spawn(move || {
                // Aggregator with a batched drain: block for the first
                // pending summary, then drain whatever else the group has
                // already queued and merge the whole batch in arrival
                // order before forwarding the group view upward *once*
                // (DASM: summaries travel up once per propagation wave —
                // batching turns N queued messages into one upward send
                // instead of N).
                let mut summary: Option<Subspace> = None;
                let mut merges = 0usize;
                while let Ok(first) = rx.recv() {
                    let mut batch = vec![first];
                    while let Ok(more) = rx.try_recv() {
                        batch.push(more);
                    }
                    for msg in batch {
                        summary = Some(match summary.take() {
                            None => msg.subspace,
                            Some(cur) => {
                                merges += 1;
                                merge_subspaces(&cur, &msg.subspace, MergeOptions::rank(rank))
                            }
                        });
                    }
                    if let Some(s) = &summary {
                        let _ = root_tx.send(Summary { subspace: s.clone() });
                    }
                }
                merges
            }));
        }
        drop(root_tx);

        // Leaves.
        let mut leaf_handles = Vec::with_capacity(self.topo.leaves);
        for (leaf, trace) in traces.into_iter().enumerate() {
            let tx = group_txs[leaf / fanout].clone();
            let epsilon = self.epsilon;
            let push_every = self.push_every;
            let cfg = self.reject_cfg;
            let latency = self.latency;
            let latency_seed = crate::rng::node_stream_seed(
                self.latency_seed,
                crate::rng::streams::CONCURRENT_PUSH_LATENCY,
                leaf,
            );
            leaf_handles.push(thread::spawn(move || {
                let mut node = NodeScheduler::new(trace.dim(), cfg);
                let mut lat_rng = Xoshiro256::seed_from_u64(latency_seed);
                // Pushes awaiting their delivery step: (deliver_at, stale
                // snapshot taken at send time). Exponential samples are
                // not monotone, so this is scanned, not a FIFO.
                let mut pending: Vec<(usize, Subspace)> = Vec::new();
                let mut last_pushed: Option<Subspace> = None;
                let mut pushes = 0usize;
                let mut suppressed = 0usize;
                for t in 0..steps_per_leaf {
                    node.observe(trace.features(t));
                    // Deliver everything whose latency has elapsed.
                    if !pending.is_empty() {
                        pending.retain(|(deliver_at, snap)| {
                            if *deliver_at <= t {
                                let _ = tx.send(Summary { subspace: snap.clone() });
                                pushes += 1;
                                false
                            } else {
                                true
                            }
                        });
                    }
                    if (t + 1) % push_every == 0 {
                        let est = node.estimate();
                        if est.is_empty() {
                            continue;
                        }
                        let moved = match &last_pushed {
                            None => true,
                            Some(prev) => prev.abs_diff(&est) > epsilon,
                        };
                        if moved {
                            last_pushed = Some(est.clone());
                            if latency.is_instant() {
                                let _ = tx.send(Summary { subspace: est });
                                pushes += 1;
                            } else {
                                let delay = latency.sample(&mut lat_rng).round() as usize;
                                pending.push((t + delay.max(1), est));
                            }
                        } else {
                            suppressed += 1;
                        }
                    }
                }
                // Whatever is still pending would arrive after the run.
                let late_drops = pending.len();
                (pushes, suppressed, late_drops, node.stats().rejected_steps)
            }));
        }
        drop(group_txs);

        // Root: same batched drain — merge every queued group summary in
        // arrival order per wake-up instead of re-waking per message.
        let rank = self.rank;
        let root_handle = thread::spawn(move || {
            let mut global: Option<Subspace> = None;
            while let Ok(first) = root_rx.recv() {
                let mut batch = vec![first];
                while let Ok(more) = root_rx.try_recv() {
                    batch.push(more);
                }
                for msg in batch {
                    global = Some(match global.take() {
                        None => msg.subspace,
                        Some(cur) => {
                            merge_subspaces(&cur, &msg.subspace, MergeOptions::rank(rank))
                        }
                    });
                }
            }
            global
        });

        let mut pushes = 0;
        let mut suppressed = 0;
        let mut late_drops = 0;
        let mut rejected_steps = 0;
        let mut dim = 0;
        for h in leaf_handles {
            let (p, s, l, r) = h.join().expect("leaf thread panicked");
            pushes += p;
            suppressed += s;
            late_drops += l;
            rejected_steps += r;
            dim = dim.max(1);
        }
        for h in agg_handles {
            let _ = h.join().expect("aggregator thread panicked");
        }
        let global_view = root_handle
            .join()
            .expect("root thread panicked")
            .unwrap_or_else(|| Subspace::empty(dim));

        FederationReport {
            leaves: self.topo.leaves,
            steps_per_leaf,
            pushes,
            suppressed,
            late_drops,
            rejected_steps,
            global_view,
            wall: std::time::Duration::ZERO,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{GeneratorConfig, TraceGenerator};

    fn traces(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
        let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
        (0..n).map(|v| gen.generate_vm_in_cluster(0, v, steps)).collect()
    }

    #[test]
    fn concurrent_run_produces_global_view() {
        let fed = ConcurrentFederation::new(TreeTopology::new(8, 4), 4, 0.0)
            .with_push_every(32);
        let report = fed.run(traces(8, 256, 42));
        assert_eq!(report.leaves, 8);
        assert_eq!(report.steps_per_leaf, 256);
        assert!(report.pushes > 0, "no pushes happened");
        assert!(!report.global_view.is_empty());
        assert_eq!(report.global_view.rank(), 4);
        assert!(report.throughput() > 0.0);
    }

    #[test]
    fn epsilon_gate_reduces_pushes() {
        let loose = ConcurrentFederation::new(TreeTopology::new(4, 4), 4, 0.0)
            .with_push_every(32)
            .run(traces(4, 512, 7));
        let gated = ConcurrentFederation::new(TreeTopology::new(4, 4), 4, 5.0)
            .with_push_every(32)
            .run(traces(4, 512, 7));
        assert!(
            gated.pushes < loose.pushes,
            "gate did not reduce pushes: {} vs {}",
            gated.pushes,
            loose.pushes
        );
        assert!(gated.suppressed > 0);
    }

    #[test]
    fn latency_delays_but_still_converges() {
        let report = ConcurrentFederation::new(TreeTopology::new(4, 4), 4, 0.0)
            .with_push_every(32)
            .with_latency(LatencyModel::Constant { steps: 16.0 }, 7)
            .run(traces(4, 512, 21));
        assert!(report.pushes > 0, "delayed pushes never delivered");
        assert!(!report.global_view.is_empty());
        // The final push of each leaf (sent at step 511) cannot arrive.
        assert!(report.late_drops > 0, "expected tail pushes to drop");
    }

    #[test]
    fn absurd_latency_drops_everything() {
        let report = ConcurrentFederation::new(TreeTopology::new(4, 4), 4, 0.0)
            .with_push_every(64)
            .with_latency(LatencyModel::Constant { steps: 1e6 }, 7)
            .run(traces(4, 256, 23));
        assert_eq!(report.pushes, 0);
        assert!(report.late_drops > 0);
        assert!(report.global_view.is_empty());
    }

    #[test]
    fn run_is_wall_clock_free_and_repeatable() {
        // Regression for the `Instant::now()` that used to live inside
        // `run()`: the report must come back with a zero wall (no clock
        // was read), the caller stamps timing via `with_wall`, and two
        // identical runs agree on every counting field.
        let mk = || {
            ConcurrentFederation::new(TreeTopology::new(4, 4), 4, 0.0)
                .with_push_every(32)
                .with_latency(LatencyModel::Exponential { mean_steps: 24.0 }, 99)
                .run(traces(4, 512, 99))
        };
        let a = mk();
        assert_eq!(a.wall, std::time::Duration::ZERO);
        let stamped = mk().with_wall(std::time::Duration::from_secs(2));
        assert_eq!(stamped.wall, std::time::Duration::from_secs(2));
        assert!((stamped.throughput() - (4.0 * 512.0) / 2.0).abs() < 1e-9);
        let b = mk();
        assert_eq!(a.pushes, b.pushes);
        assert_eq!(a.suppressed, b.suppressed);
        assert_eq!(a.late_drops, b.late_drops);
        assert_eq!(a.rejected_steps, b.rejected_steps);
    }

    #[test]
    fn single_leaf_degenerate_tree() {
        let fed = ConcurrentFederation::new(TreeTopology::new(1, 2), 4, 0.0)
            .with_push_every(64);
        let report = fed.run(traces(1, 256, 3));
        assert!(!report.global_view.is_empty());
    }
}
