"""AOT lowering: JAX/Pallas graphs → HLO *text* artifacts + manifest.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run from ``python/``:  ``python -m compile.aot --out ../artifacts``
(this is what ``make artifacts`` does). Python never runs after this.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default compiled configuration (the paper's evaluation setting):
# d = 52 VM metrics, r = 4 (§7.1), block b = 32, z-score lag = 10.
DEFAULT_D = 52
DEFAULT_R = 4
DEFAULT_B = 32
DEFAULT_LAG = 10

DTYPE = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def build_artifacts(d, r, b, lag):
    """Lower the three graphs at one (d, r, b, lag) configuration.

    Returns {name: (hlo_text, manifest_entry)}.
    """
    arts = {}

    # --- fpca_update(U, S, B, forget) -> (U', S') ---------------------
    lowered = jax.jit(model.fpca_update).lower(
        _spec((d, r)), _spec((r,)), _spec((d, b)), _spec(())
    )
    arts["fpca_update"] = (
        to_hlo_text(lowered),
        {
            "inputs": [
                {"name": "u", "shape": [d, r]},
                {"name": "s", "shape": [r]},
                {"name": "block", "shape": [d, b]},
                {"name": "forget", "shape": []},
            ],
            "outputs": [
                {"name": "u_new", "shape": [d, r]},
                {"name": "s_new", "shape": [r]},
            ],
        },
    )

    # --- merge_subspaces(U1, S1, U2, S2, forget) -> (U, S) -------------
    lowered = jax.jit(model.merge_subspaces).lower(
        _spec((d, r)), _spec((r,)), _spec((d, r)), _spec((r,)), _spec(())
    )
    arts["merge_subspaces"] = (
        to_hlo_text(lowered),
        {
            "inputs": [
                {"name": "u1", "shape": [d, r]},
                {"name": "s1", "shape": [r]},
                {"name": "u2", "shape": [d, r]},
                {"name": "s2", "shape": [r]},
                {"name": "forget", "shape": []},
            ],
            "outputs": [
                {"name": "u", "shape": [d, r]},
                {"name": "s", "shape": [r]},
            ],
        },
    )

    # --- project_detect(U, S, Y, buf, seen) -> (flags, reject, buf', seen')
    lowered = jax.jit(model.project_detect).lower(
        _spec((d, r)),
        _spec((r,)),
        _spec((b, d)),
        _spec((r, lag)),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    arts["project_detect"] = (
        to_hlo_text(lowered),
        {
            "inputs": [
                {"name": "u", "shape": [d, r]},
                {"name": "s", "shape": [r]},
                {"name": "y_block", "shape": [b, d]},
                {"name": "buf", "shape": [r, lag]},
                {"name": "seen", "shape": [], "dtype": "s32"},
            ],
            "outputs": [
                {"name": "flags", "shape": [b, r]},
                {"name": "reject", "shape": [b]},
                {"name": "buf_new", "shape": [r, lag]},
                {"name": "seen_new", "shape": [], "dtype": "s32"},
            ],
        },
    )
    return arts


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--dim", type=int, default=DEFAULT_D)
    ap.add_argument("--rank", type=int, default=DEFAULT_R)
    ap.add_argument("--block", type=int, default=DEFAULT_B)
    ap.add_argument("--lag", type=int, default=DEFAULT_LAG)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    arts = build_artifacts(args.dim, args.rank, args.block, args.lag)

    manifest = {
        "config": {
            "dim": args.dim,
            "rank": args.rank,
            "block": args.block,
            "lag": args.lag,
            "dtype": "f32",
        },
        "artifacts": {},
    }
    for name, (text, entry) in arts.items():
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        entry["file"] = fname
        manifest["artifacts"][name] = entry
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
