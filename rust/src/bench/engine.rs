//! Fleet-scale engine benchmark: catalog scenarios swept over fleet
//! sizes through the **streaming** trace source, timed end to end.
//!
//! This is the perf-trajectory artifact behind `pronto bench engine` (and
//! the `engine_scale` bench target): each run drives one scenario at one
//! fleet size with cost-free `always` admission policies, so the measured
//! wall time is the engine + telemetry-generation hot path, not FPCA.
//! Results serialize to `BENCH_engine.json` — machine-readable so
//! successive PRs can diff events/s.
//!
//! ```text
//! pronto bench engine                      # 100/1k/5k nodes, default set
//! pronto bench engine --quick              # CI smoke sizing
//! pronto bench engine --sizes 5000 --steps 10000 --scenarios large-fleet
//! ```

use crate::scheduler::{Admission, RandomPolicy};
use crate::ser::JsonValue;
use crate::sim::{DiscreteEventEngine, Scenario};
use crate::telemetry::{fleet_members, GeneratorConfig, TraceGenerator, TraceSource};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::time::Instant;

/// Cluster grouping for generated fleets (matches the scenario bench).
const BENCH_FANOUT: usize = 8;

/// Scenarios the default sweep covers: the paper's baseline, the two
/// capacity overloads, and the two scale entries.
pub const DEFAULT_BENCH_SCENARIOS: &[&str] =
    &["baseline-poisson", "capacity", "queue-aware", "large-fleet", "flash-crowd"];

/// One extra sweep row at a scale and observe-pool width of its own,
/// appended after the size ladder. The canonical use is the 100k-node
/// `large-fleet` row: it needs a step count and thread width the ladder
/// would make prohibitively slow fleet-wide, and `pronto bench diff`
/// joins rows by `(scenario, nodes, threads)`, so a scale row diffs
/// against the baseline independently of the ladder rows.
#[derive(Debug, Clone)]
pub struct ScaleRow {
    pub scenario: String,
    pub nodes: usize,
    pub steps: usize,
    pub threads: usize,
}

/// One sweep configuration.
#[derive(Debug, Clone)]
pub struct EngineBenchConfig {
    /// Fleet sizes to sweep (each scenario's own `nodes` is overridden).
    pub sizes: Vec<usize>,
    /// Steps per run.
    pub steps: usize,
    pub seed: u64,
    /// Catalog names to run.
    pub scenarios: Vec<String>,
    /// Observe-loop worker threads per run (reports are byte-identical
    /// across widths; this sweeps wall time only). Recorded per row so
    /// `pronto bench diff` never compares across widths.
    pub threads: usize,
    /// Extra rows at their own scale/steps/width (the 100k-node row),
    /// run after the ladder with the sweep's seed.
    pub scale_rows: Vec<ScaleRow>,
    /// Quick sizing (CI smoke) — recorded in the artifact.
    pub quick: bool,
}

/// The default scale row: 100k nodes of `large-fleet` through the
/// streaming source at 4 observe threads (`steps` differs between full
/// and quick sizing).
fn large_fleet_row(steps: usize) -> ScaleRow {
    ScaleRow { scenario: "large-fleet".into(), nodes: 100_000, steps, threads: 4 }
}

/// The quarter-million row: 250k nodes of `large-fleet` at 4 observe
/// threads. Runs after the 100k row with a smaller step budget — the
/// point is the per-event cost at 2.5× the fleet footprint (sharded
/// merge fan-in, SoA state, timing wheel), not a long trajectory.
fn quarter_million_row(steps: usize) -> ScaleRow {
    ScaleRow { scenario: "large-fleet".into(), nodes: 250_000, steps, threads: 4 }
}

impl EngineBenchConfig {
    /// Full sizing: the 100 / 1 000 / 5 000-node ladder plus the
    /// 100k-node × 200-step and 250k-node × 120-step 4-thread
    /// `large-fleet` scale rows.
    pub fn full() -> Self {
        Self {
            sizes: vec![100, 1_000, 5_000],
            steps: 1_000,
            seed: 2021,
            scenarios: DEFAULT_BENCH_SCENARIOS.iter().map(|s| s.to_string()).collect(),
            threads: 1,
            scale_rows: vec![large_fleet_row(200), quarter_million_row(120)],
            quick: false,
        }
    }

    /// Quick sizing for smoke runs. Keeps both scale rows (at smoke
    /// step counts) so CI exercises the 100k and 250k large-fleet paths
    /// end to end on every run.
    pub fn quick() -> Self {
        Self {
            sizes: vec![50, 200],
            steps: 200,
            seed: 2021,
            scenarios: DEFAULT_BENCH_SCENARIOS.iter().map(|s| s.to_string()).collect(),
            threads: 1,
            scale_rows: vec![large_fleet_row(20), quarter_million_row(12)],
            quick: true,
        }
    }

    /// Honour `PRONTO_BENCH_QUICK=1`.
    pub fn from_env() -> Self {
        if std::env::var("PRONTO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
            Self::quick()
        } else {
            Self::full()
        }
    }
}

/// One timed run.
#[derive(Debug, Clone)]
pub struct EngineBenchRun {
    pub scenario: String,
    pub nodes: usize,
    pub steps: usize,
    pub seed: u64,
    /// Observe-loop worker threads this row ran with.
    pub threads: usize,
    pub wall_ms: f64,
    /// Events the engine dispatched (`SimReport::events_processed`).
    pub events: usize,
    pub events_per_sec: f64,
    pub jobs_arrived: usize,
    pub jobs_completed: usize,
    pub peak_queue_len: usize,
    pub peak_inflight: usize,
}

impl EngineBenchRun {
    pub fn to_json(&self) -> JsonValue {
        let mut m = BTreeMap::new();
        let num = |x: usize| JsonValue::Number(x as f64);
        m.insert("scenario".into(), JsonValue::String(self.scenario.clone()));
        m.insert("nodes".into(), num(self.nodes));
        m.insert("steps".into(), num(self.steps));
        m.insert("seed".into(), JsonValue::String(self.seed.to_string()));
        m.insert("threads".into(), num(self.threads));
        m.insert("wall_ms".into(), JsonValue::Number(self.wall_ms));
        m.insert("events".into(), num(self.events));
        m.insert("events_per_sec".into(), JsonValue::Number(self.events_per_sec));
        m.insert("jobs_arrived".into(), num(self.jobs_arrived));
        m.insert("jobs_completed".into(), num(self.jobs_completed));
        m.insert("peak_queue_len".into(), num(self.peak_queue_len));
        m.insert("peak_inflight".into(), num(self.peak_inflight));
        JsonValue::Object(m)
    }
}

/// Run one scenario at one fleet size through the streaming source with
/// `always`-accept policies, timed end to end.
///
/// Every run builds its generator, source, policies, engine, and report
/// from scratch — rows share **no** scratch state, so any row of a sweep
/// reproduces identically when run in isolation (audited by
/// `sweep_rows_match_isolated_runs` below; `pronto bench diff` depends
/// on rows being independent measurements).
pub fn bench_engine_run(
    name: &str,
    nodes: usize,
    steps: usize,
    seed: u64,
    threads: usize,
) -> Result<EngineBenchRun> {
    let scenario = Scenario::named(name)
        .ok_or_else(|| anyhow!("unknown bench scenario '{name}'"))?
        .with_nodes(nodes)
        .with_steps(steps)
        .with_seed(seed)
        .with_threads(threads);
    scenario.validate()?;
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    let members = fleet_members(nodes, BENCH_FANOUT);
    let source = TraceSource::streaming(&gen, &members, steps, scenario.score_window);
    let policies: Vec<Box<dyn Admission>> = (0..nodes)
        .map(|i| {
            Box::new(RandomPolicy::always_accept(seed ^ i as u64)) as Box<dyn Admission>
        })
        .collect();
    let engine = DiscreteEventEngine::try_from_source(scenario, source, policies)?;
    let t0 = Instant::now();
    let report = engine.run();
    let wall = t0.elapsed();
    let wall_ms = wall.as_secs_f64() * 1e3;
    Ok(EngineBenchRun {
        scenario: name.to_string(),
        nodes,
        steps,
        seed,
        threads,
        wall_ms,
        events: report.events_processed,
        events_per_sec: report.events_processed as f64 / wall.as_secs_f64().max(1e-9),
        jobs_arrived: report.jobs_arrived,
        jobs_completed: report.jobs_completed,
        peak_queue_len: report.peak_queue_len,
        peak_inflight: report.peak_inflight,
    })
}

/// Run the full sweep, logging one line per run to stderr: the size
/// ladder first, then every configured [`ScaleRow`] (the 100k-node
/// large-fleet row in the default configs) with its own steps/threads.
pub fn bench_engine(cfg: &EngineBenchConfig) -> Result<Vec<EngineBenchRun>> {
    let mut runs =
        Vec::with_capacity(cfg.sizes.len() * cfg.scenarios.len() + cfg.scale_rows.len());
    for &nodes in &cfg.sizes {
        for name in &cfg.scenarios {
            let run = bench_engine_run(name, nodes, cfg.steps, cfg.seed, cfg.threads)?;
            log_run(&run);
            runs.push(run);
        }
    }
    for row in &cfg.scale_rows {
        let run = bench_engine_run(&row.scenario, row.nodes, row.steps, cfg.seed, row.threads)?;
        log_run(&run);
        runs.push(run);
    }
    Ok(runs)
}

fn log_run(run: &EngineBenchRun) {
    eprintln!(
        "bench engine: {:<18} {:>6} nodes x {} steps x {} threads — \
         {:>10.1} ms, {:>12.0} events/s, peak queue {}",
        run.scenario,
        run.nodes,
        run.steps,
        run.threads,
        run.wall_ms,
        run.events_per_sec,
        run.peak_queue_len
    );
}

/// The `BENCH_engine.json` document (schema documented in the README):
/// sweep metadata plus one entry per run.
pub fn bench_engine_report(cfg: &EngineBenchConfig, runs: &[EngineBenchRun]) -> JsonValue {
    let mut m = BTreeMap::new();
    m.insert("bench".into(), JsonValue::String("engine".into()));
    // v2: rows (and the sweep) carry `threads`.
    m.insert("schema_version".into(), JsonValue::Number(2.0));
    m.insert("quick".into(), JsonValue::Bool(cfg.quick));
    m.insert("policy".into(), JsonValue::String("always".into()));
    m.insert("trace_source".into(), JsonValue::String("streaming".into()));
    m.insert("steps".into(), JsonValue::Number(cfg.steps as f64));
    m.insert("seed".into(), JsonValue::String(cfg.seed.to_string()));
    m.insert("threads".into(), JsonValue::Number(cfg.threads as f64));
    m.insert(
        "sizes".into(),
        JsonValue::Array(cfg.sizes.iter().map(|&s| JsonValue::Number(s as f64)).collect()),
    );
    m.insert(
        "scale_rows".into(),
        JsonValue::Array(
            cfg.scale_rows
                .iter()
                .map(|r| {
                    let mut row = BTreeMap::new();
                    row.insert("scenario".into(), JsonValue::String(r.scenario.clone()));
                    row.insert("nodes".into(), JsonValue::Number(r.nodes as f64));
                    row.insert("steps".into(), JsonValue::Number(r.steps as f64));
                    row.insert("threads".into(), JsonValue::Number(r.threads as f64));
                    JsonValue::Object(row)
                })
                .collect(),
        ),
    );
    m.insert(
        "runs".into(),
        JsonValue::Array(runs.iter().map(EngineBenchRun::to_json).collect()),
    );
    JsonValue::Object(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_produces_sane_numbers() {
        let run = bench_engine_run("large-fleet", 40, 120, 7, 1).unwrap();
        assert_eq!(run.nodes, 40);
        assert_eq!(run.steps, 120);
        assert_eq!(run.threads, 1);
        assert!(run.events > 120, "fewer events than ticks: {}", run.events);
        assert!(run.wall_ms > 0.0);
        assert!(run.events_per_sec > 0.0);
        assert!(run.jobs_arrived > 0);
    }

    #[test]
    fn unknown_scenario_is_an_error() {
        assert!(bench_engine_run("no-such-scenario", 4, 50, 1, 1).is_err());
        assert!(
            bench_engine_run("baseline-poisson", 4, 50, 1, 0).is_err(),
            "zero threads must be rejected by scenario validation"
        );
    }

    #[test]
    fn sweep_rows_match_isolated_runs() {
        // Audit for the row-independence contract: `bench_engine` builds
        // every row from scratch (no reused scratch report), so each row
        // of a sweep must equal the same configuration run in isolation
        // on every deterministic field (wall time is the one legitimate
        // difference). A shared-state regression — e.g. a reused engine
        // or generator between rows — would show up as drift in the
        // later rows.
        let cfg = EngineBenchConfig {
            sizes: vec![8, 14],
            steps: 80,
            seed: 11,
            scenarios: vec!["baseline-poisson".into(), "capacity".into()],
            threads: 2,
            scale_rows: vec![],
            quick: true,
        };
        let sweep = bench_engine(&cfg).unwrap();
        assert_eq!(sweep.len(), 4);
        for row in &sweep {
            let solo = bench_engine_run(&row.scenario, row.nodes, row.steps, row.seed, row.threads)
                .unwrap();
            assert_eq!(solo.events, row.events, "{} x {}", row.scenario, row.nodes);
            assert_eq!(solo.jobs_arrived, row.jobs_arrived);
            assert_eq!(solo.jobs_completed, row.jobs_completed);
            assert_eq!(solo.peak_queue_len, row.peak_queue_len);
            assert_eq!(solo.peak_inflight, row.peak_inflight);
        }
        // Re-seeding per fleet size is real: different sizes are
        // different runs, not replays of each other. Compare the
        // *capacity* rows — the no-capacity baseline's event count is
        // fleet-size-invariant by construction (same seed-derived
        // arrival/duration streams, unbounded hosts), but a capacity run
        // sees a different slot budget per size.
        assert!(
            sweep[1].events != sweep[3].events
                || sweep[1].jobs_completed != sweep[3].jobs_completed
                || sweep[1].peak_queue_len != sweep[3].peak_queue_len,
            "capacity rows at different fleet sizes produced identical runs"
        );
    }

    #[test]
    fn scale_rows_append_after_the_ladder_with_their_own_shape() {
        // A miniature stand-in for the 100k large-fleet row: the scale
        // row must run after every ladder row, with its *own* nodes,
        // steps, and thread width (not the sweep's), and land in the
        // report's `runs` array like any other row.
        let cfg = EngineBenchConfig {
            sizes: vec![6],
            steps: 40,
            seed: 5,
            scenarios: vec!["baseline-poisson".into()],
            threads: 1,
            scale_rows: vec![ScaleRow {
                scenario: "large-fleet".into(),
                nodes: 30,
                steps: 25,
                threads: 2,
            }],
            quick: true,
        };
        let runs = bench_engine(&cfg).unwrap();
        assert_eq!(runs.len(), 2);
        let scale = &runs[1];
        assert_eq!(scale.scenario, "large-fleet");
        assert_eq!(scale.nodes, 30);
        assert_eq!(scale.steps, 25);
        assert_eq!(scale.threads, 2);
        assert_eq!(scale.seed, cfg.seed, "scale rows run with the sweep seed");
        // The descriptor is recorded in the report metadata so a diff of
        // two artifacts can explain a missing/extra row.
        let doc = bench_engine_report(&cfg, &runs);
        let text = doc.to_string();
        let parsed = crate::ser::parse_json(&text).expect("valid json");
        let JsonValue::Array(rows) = parsed.get("scale_rows").expect("scale_rows key") else {
            panic!("scale_rows must be an array")
        };
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("nodes").and_then(JsonValue::as_usize), Some(30));
        assert_eq!(rows[0].get("threads").and_then(JsonValue::as_usize), Some(2));
    }

    #[test]
    fn default_configs_carry_both_scale_rows() {
        // The perf trajectory tracks two fixed large-fleet points: 100k
        // and 250k nodes, both at 4 observe threads. `bench diff` joins
        // rows by (scenario, nodes, threads), so these must not drift.
        for cfg in [EngineBenchConfig::full(), EngineBenchConfig::quick()] {
            assert_eq!(cfg.scale_rows.len(), 2);
            assert_eq!(cfg.scale_rows[0].nodes, 100_000);
            assert_eq!(cfg.scale_rows[1].nodes, 250_000);
            assert!(cfg
                .scale_rows
                .iter()
                .all(|r| r.scenario == "large-fleet" && r.threads == 4));
        }
        assert_eq!(EngineBenchConfig::quick().scale_rows[1].steps, 12);
        assert_eq!(EngineBenchConfig::full().scale_rows[1].steps, 120);
    }

    #[test]
    fn row_json_schema_keys_are_pinned() {
        // `pronto bench diff` joins rows by (scenario, nodes, threads)
        // and reads events_per_sec; this pins the exact key set so a
        // schema drift fails here instead of silently breaking diffs.
        let run = bench_engine_run("baseline-poisson", 6, 40, 2, 1).unwrap();
        let JsonValue::Object(m) = run.to_json() else { panic!("row must be an object") };
        let keys: Vec<&str> = m.keys().map(String::as_str).collect();
        assert_eq!(
            keys,
            vec![
                "events",
                "events_per_sec",
                "jobs_arrived",
                "jobs_completed",
                "nodes",
                "peak_inflight",
                "peak_queue_len",
                "scenario",
                "seed",
                "steps",
                "threads",
                "wall_ms",
            ]
        );
    }

    #[test]
    fn report_document_is_valid_json_with_runs() {
        let cfg = EngineBenchConfig {
            sizes: vec![8],
            steps: 60,
            seed: 3,
            scenarios: vec!["baseline-poisson".into(), "flash-crowd".into()],
            threads: 1,
            scale_rows: vec![],
            quick: true,
        };
        let runs = bench_engine(&cfg).unwrap();
        assert_eq!(runs.len(), 2);
        let doc = bench_engine_report(&cfg, &runs);
        let text = doc.to_string();
        let parsed = crate::ser::parse_json(&text).expect("valid json");
        assert_eq!(
            parsed.get("bench").and_then(JsonValue::as_str),
            Some("engine")
        );
        assert_eq!(
            parsed.get("schema_version").and_then(JsonValue::as_usize),
            Some(2)
        );
        let runs_v = parsed.get("runs").expect("runs key");
        match runs_v {
            JsonValue::Array(a) => {
                assert_eq!(a.len(), 2);
                assert!(a[0].get("events_per_sec").is_some());
                assert!(a[0].get("peak_queue_len").is_some());
                assert_eq!(a[0].get("threads").and_then(JsonValue::as_usize), Some(1));
            }
            other => panic!("runs must be an array, got {other:?}"),
        }
    }
}
