//! Streaming spike detection.
//!
//! Implements the robust z-score peak detector of van Brakel (2014) that
//! Algorithm 1 (Reject-Job) embeds: a per-signal lag buffer of dampened
//! history, running mean/std filters, threshold `alpha` (z-scores) and
//! influence `beta` for detected peaks. [`ZScoreDetector`] tracks one scalar
//! signal; [`MultiDetector`] tracks the r projection signals of a node;
//! [`SlidingWindow`] provides the left/right-sided spike bookkeeping of
//! Figure 5 used by the evaluation.

pub mod window;
mod zscore;

pub use window::{
    classify_spike, lead_time, left_span, raise_true_positive, right_span, SideCounts,
    SlidingWindow, SpikeSide,
};
pub use zscore::{MultiDetector, Spike, ZScoreConfig, ZScoreDetector};
