//! Minimal offline subset of the `once_cell` crate: `sync::Lazy` built on
//! `std::sync::OnceLock`. Only the API PRONTO uses.

pub mod sync {
    use std::cell::Cell;
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access, safe for `static` use.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: Cell<Option<F>>,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Self {
            Self { cell: OnceLock::new(), init: Cell::new(Some(init)) }
        }
    }

    impl<T, F: FnOnce() -> T> Lazy<T, F> {
        /// Force evaluation and return a reference to the value.
        pub fn force(this: &Self) -> &T {
            this.cell.get_or_init(|| match this.init.take() {
                Some(f) => f(),
                None => panic!("Lazy instance poisoned during initialization"),
            })
        }
    }

    impl<T, F: FnOnce() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Self::force(self)
        }
    }

    // SAFETY: mirrors once_cell — initialization is serialized by OnceLock;
    // the Cell<Option<F>> is only taken inside that critical section.
    unsafe impl<T: Send + Sync, F: Send> Sync for Lazy<T, F> {}

    #[cfg(test)]
    mod tests {
        use super::*;

        static GLOBAL: Lazy<Vec<u32>> = Lazy::new(|| vec![1, 2, 3]);

        #[test]
        fn initializes_once_and_derefs() {
            assert_eq!(GLOBAL.len(), 3);
            assert_eq!(*GLOBAL, vec![1, 2, 3]);
        }

        #[test]
        fn lazy_with_closure() {
            let calls = std::sync::atomic::AtomicU32::new(0);
            let l = Lazy::new(|| {
                calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                42u32
            });
            assert_eq!(*l, 42);
            assert_eq!(*l, 42);
            assert_eq!(calls.load(std::sync::atomic::Ordering::SeqCst), 1);
        }
    }
}
