//! The PJRT client wrapper: compile HLO-text artifacts once, execute many.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. The jax side lowers with
//! `return_tuple=True`, so every artifact's output is one tuple literal.

use super::manifest::{ArtifactEntry, Manifest, TensorSpec};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A tensor crossing the runtime boundary (host side, f32 or i32 payload).
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor"),
        }
    }
}

/// One compiled artifact ready to execute.
struct LoadedArtifact {
    entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// The runtime: one PJRT CPU client plus the compiled executables.
///
/// Compilation happens once at construction (or lazily per artifact);
/// `execute` is the request-path entry and does no Python, no disk I/O.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    loaded: BTreeMap<String, LoadedArtifact>,
}

// SAFETY: the PJRT C API is documented thread-safe (clients, loaded
// executables, and buffers may be used from multiple threads); the wrapper
// types in the `xla` crate are !Send/!Sync only because they hold raw
// pointers. `XlaRuntime` never exposes interior mutation after
// construction — `execute` is &self and PJRT serializes internally.
unsafe impl Send for XlaRuntime {}
unsafe impl Sync for XlaRuntime {}

impl XlaRuntime {
    /// Load the manifest from `dir` and compile all artifacts eagerly.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        let mut rt = Self {
            client,
            dir: dir.to_path_buf(),
            manifest,
            loaded: BTreeMap::new(),
        };
        let names: Vec<String> = rt.manifest.artifacts.keys().cloned().collect();
        for name in names {
            rt.compile_artifact(&name)?;
        }
        Ok(rt)
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> Result<Self> {
        Self::load(&super::artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn compile_artifact(&mut self, name: &str) -> Result<()> {
        let entry = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.loaded.insert(name.to_string(), LoadedArtifact { entry, exe });
        Ok(())
    }

    fn literal_for(spec: &TensorSpec, tensor: &HostTensor) -> Result<xla::Literal> {
        if tensor.len() != spec.elements() {
            bail!(
                "input '{}' has {} elements, expected {} (shape {:?})",
                spec.name,
                tensor.len(),
                spec.elements(),
                spec.shape
            );
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match (tensor, spec.dtype.as_str()) {
            (HostTensor::F32(v), "f32") => xla::Literal::vec1(v.as_slice()),
            (HostTensor::I32(v), "s32") => xla::Literal::vec1(v.as_slice()),
            (t, d) => bail!("dtype mismatch for '{}': host {t:?} vs spec {d}", spec.name),
        };
        if dims.is_empty() {
            // Scalar: reshape the 1-element vector to rank 0.
            lit.reshape(&[]).map_err(|e| anyhow!("scalar reshape: {e:?}"))
        } else {
            lit.reshape(&dims)
                .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
        }
    }

    fn tensor_from_literal(spec: &TensorSpec, lit: &xla::Literal) -> Result<HostTensor> {
        match spec.dtype.as_str() {
            "f32" => Ok(HostTensor::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow!("f32 readback: {e:?}"))?,
            )),
            "s32" => Ok(HostTensor::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow!("i32 readback: {e:?}"))?,
            )),
            d => bail!("unsupported output dtype {d}"),
        }
    }

    /// Execute an artifact with positional inputs (row-major host buffers,
    /// order/shape per the manifest). Returns one [`HostTensor`] per output.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let art = self
            .loaded
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        if inputs.len() != art.entry.inputs.len() {
            bail!(
                "artifact '{name}' takes {} inputs, got {}",
                art.entry.inputs.len(),
                inputs.len()
            );
        }
        let literals: Vec<xla::Literal> = art
            .entry
            .inputs
            .iter()
            .zip(inputs)
            .map(|(spec, t)| Self::literal_for(spec, t))
            .collect::<Result<_>>()?;

        let result = art
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync readback: {e:?}"))?;
        // jax lowered with return_tuple=True: decompose the tuple.
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("tuple decompose: {e:?}"))?;
        if parts.len() != art.entry.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                art.entry.outputs.len()
            );
        }
        art.entry
            .outputs
            .iter()
            .zip(parts.iter())
            .map(|(spec, lit)| Self::tensor_from_literal(spec, lit))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_available;

    fn runtime() -> Option<std::sync::Arc<XlaRuntime>> {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        crate::runtime::shared_runtime()
    }

    #[test]
    fn loads_and_compiles_all_artifacts() {
        let Some(rt) = runtime() else { return };
        assert!(rt.manifest().artifacts.len() >= 3);
    }

    #[test]
    fn fpca_update_executes_and_matches_native() {
        let Some(rt) = runtime() else { return };
        let cfg = rt.manifest().config;
        let (d, r, b) = (cfg.dim, cfg.rank, cfg.block);

        // Random block; empty previous estimate.
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(77);
        let block_rm: Vec<f32> = (0..d * b).map(|_| rng.normal() as f32).collect();
        let inputs = vec![
            HostTensor::F32(vec![0.0; d * r]),
            HostTensor::F32(vec![0.0; r]),
            HostTensor::F32(block_rm.clone()),
            HostTensor::F32(vec![1.0]),
        ];
        let out = rt.execute("fpca_update", &inputs).expect("execute");
        assert_eq!(out.len(), 2);
        let s_new = out[1].as_f32().unwrap();
        assert_eq!(s_new.len(), r);

        // Native oracle: truncated SVD of the block.
        let mut block = crate::linalg::Mat::zeros(d, b);
        for i in 0..d {
            for j in 0..b {
                block.set(i, j, block_rm[i * b + j] as f64);
            }
        }
        let svd = crate::linalg::svd_truncated(&block, r);
        for (xla_s, native_s) in s_new.iter().zip(svd.sigma.iter()) {
            let rel = (f64::from(*xla_s) - native_s).abs() / native_s.max(1e-9);
            assert!(rel < 0.05, "sigma mismatch: {xla_s} vs {native_s}");
        }
    }

    #[test]
    fn project_detect_executes() {
        let Some(rt) = runtime() else { return };
        let cfg = rt.manifest().config;
        let (d, r, b, lag) = (cfg.dim, cfg.rank, cfg.block, cfg.lag);
        // Identity-ish embedding on the first r coordinates.
        let mut u = vec![0.0f32; d * r];
        for j in 0..r {
            u[j * r + j] = 1.0; // row-major (d, r): row j, col j
        }
        let inputs = vec![
            HostTensor::F32(u),
            HostTensor::F32(vec![1.0; r]),
            HostTensor::F32(vec![0.5; b * d]),
            HostTensor::F32(vec![0.0; r * lag]),
            HostTensor::I32(vec![0]),
        ];
        let out = rt.execute("project_detect", &inputs).expect("execute");
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].as_f32().unwrap().len(), b * r);
        assert_eq!(out[1].as_f32().unwrap().len(), b);
        // Constant stream: no rejections.
        assert!(out[1].as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert_eq!(out[3].as_i32().unwrap()[0], b as i32);
    }

    #[test]
    fn wrong_arity_is_an_error() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("fpca_update", &[]).is_err());
    }
}
