//! XLA-artifact vs native parity: the AOT-compiled HLO path must agree
//! with the Rust oracle over realistic multi-block streams. Skipped when
//! artifacts are absent (`make artifacts` not run).

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::baselines::StreamingEmbedding;
use pronto::fpca::{FpcaEdge, FpcaEdgeConfig, Subspace};
use pronto::linalg::subspace_distance;
use pronto::runtime::{shared_runtime, XlaFpca, XlaProjectDetect};
use pronto::scheduler::{RejectConfig, RejectJob};
use pronto::telemetry::{GeneratorConfig, TraceGenerator};

#[test]
fn fpca_parity_over_many_blocks() {
    let Some(rt) = shared_runtime() else {
        eprintln!("artifacts missing; skipping");
        return;
    };
    let cfg = rt.manifest().config;
    let d = cfg.dim;
    let gen = TraceGenerator::new(GeneratorConfig::default(), 99);
    let trace = gen.generate_vm(0, cfg.block * 12);

    let mut xla = XlaFpca::new(rt, d).unwrap();
    let mut native = FpcaEdge::new(
        d,
        FpcaEdgeConfig {
            initial_rank: cfg.rank,
            max_rank: cfg.rank,
            block_size: cfg.block,
            adaptive_rank: false,
            ..Default::default()
        },
    );
    // Feed standardized-ish values: raw counters stress f32 less than the
    // pipeline's standardizer would, so scale down by a constant.
    for t in 0..trace.len() {
        let y: Vec<f64> = trace.features(t).iter().map(|x| x / 100.0).collect();
        StreamingEmbedding::observe(&mut xla, &y);
        StreamingEmbedding::observe(&mut native, &y);
    }
    assert_eq!(xla.blocks_processed(), 12);

    let ex = StreamingEmbedding::estimate(&xla);
    let en = StreamingEmbedding::estimate(&native);
    // Singular values within f32-accumulation tolerance.
    for (a, b) in ex.sigma.iter().zip(en.sigma.iter()) {
        let rel = (a - b).abs() / b.max(1e-6);
        assert!(rel < 0.05, "sigma mismatch {a} vs {b} (rel {rel})");
    }
    // Subspace agreement on the dominant components.
    let dist = subspace_distance(&ex.truncate(2).u, &en.truncate(2).u);
    assert!(dist < 0.1, "dominant subspace diverged: {dist}");
}

#[test]
fn project_detect_parity_over_stream() {
    let Some(rt) = shared_runtime() else {
        eprintln!("artifacts missing; skipping");
        return;
    };
    let cfg = rt.manifest().config;
    let (d, r, b) = (cfg.dim, cfg.rank, cfg.block);
    let mut rng = pronto::rng::Xoshiro256::seed_from_u64(5);
    let u = pronto::proptest::gen_orthonormal(&mut rng, d, r);
    let est = Subspace::new(u.clone(), vec![4.0, 3.0, 2.0, 1.0]);

    // Stream with injected aligned spikes at known offsets.
    let blocks = 4;
    let mut ys = vec![0.0f32; blocks * b * d];
    for t in 0..blocks * b {
        for i in 0..d {
            ys[t * d + i] = (0.05 * rng.normal()) as f32;
        }
    }
    for &spike_t in &[40usize, 70, 100] {
        for i in 0..d {
            ys[spike_t * d + i] += (30.0 * u.get(i, 0)) as f32;
        }
    }

    let mut xpd = XlaProjectDetect::new(rt);
    let mut xla_rejects = Vec::new();
    for blk in 0..blocks {
        let slice = &ys[blk * b * d..(blk + 1) * b * d];
        let (_, reject) = xpd.run_block(&est, slice).unwrap();
        xla_rejects.extend(reject);
    }

    let mut rj = RejectJob::new(RejectConfig { max_rank: r, ..Default::default() });
    let mut native_rejects = Vec::new();
    for t in 0..blocks * b {
        let row: Vec<f64> = (0..d).map(|i| f64::from(ys[t * d + i])).collect();
        native_rejects.push(rj.observe(&est, &row) as u8 as f32);
    }

    assert_eq!(xla_rejects.len(), native_rejects.len());
    let diffs: Vec<usize> = (0..xla_rejects.len())
        .filter(|&t| xla_rejects[t] != native_rejects[t])
        .collect();
    assert!(
        diffs.is_empty(),
        "rejection signals diverge at steps {diffs:?}"
    );
    // And the injected spikes were caught by both.
    for &t in &[40usize, 70, 100] {
        assert_eq!(xla_rejects[t], 1.0, "spike at {t} missed");
    }
}

#[test]
fn merge_artifact_parity_randomized() {
    let Some(rt) = shared_runtime() else {
        eprintln!("artifacts missing; skipping");
        return;
    };
    let cfg = rt.manifest().config;
    for seed in 0..5u64 {
        let mut rng = pronto::rng::Xoshiro256::seed_from_u64(seed);
        let s1 = Subspace::new(
            pronto::proptest::gen_orthonormal(&mut rng, cfg.dim, cfg.rank),
            pronto::proptest::gen_spectrum(&mut rng, cfg.rank),
        );
        let s2 = Subspace::new(
            pronto::proptest::gen_orthonormal(&mut rng, cfg.dim, cfg.rank),
            pronto::proptest::gen_spectrum(&mut rng, cfg.rank),
        );
        let xla = pronto::runtime::xla_merge(&rt, &s1, &s2, 0.9).unwrap();
        let native = pronto::fpca::merge_subspaces(
            &s1,
            &s2,
            pronto::fpca::MergeOptions { rank: cfg.rank, forget: 0.9, enhance: 1.0 },
        );
        for (a, b) in xla.sigma.iter().zip(native.sigma.iter()) {
            let rel = (a - b).abs() / b.max(1e-6);
            assert!(rel < 0.03, "seed {seed}: sigma {a} vs {b}");
        }
    }
}
