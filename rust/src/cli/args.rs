//! Minimal argument parser (clap replacement): `--key value`, `--flag`,
//! and positional arguments, with typed accessors and unknown-flag errors.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed arguments for one subcommand.
#[derive(Debug, Default)]
pub struct Args {
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `flag_names` lists boolean flags (no value);
    /// everything else starting with `--` consumes the next token.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| anyhow!("--{name} expects a value"))?;
                    out.options.insert(name.to_string(), val.clone());
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad integer '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name}: bad number '{v}'")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error if any option other than the allowed set was supplied.
    pub fn reject_unknown(&self, allowed: &[&str]) -> Result<()> {
        for key in self.options.keys() {
            if !allowed.contains(&key.as_str()) {
                bail!("unknown option --{key} (allowed: {allowed:?})");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = Args::parse(&raw(&["--nodes", "8", "--quick", "sim"]), &["quick"]).unwrap();
        assert_eq!(a.get("nodes"), Some("8"));
        assert!(a.flag("quick"));
        assert_eq!(a.positional(), &["sim".to_string()]);
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = Args::parse(&raw(&["--n", "5"]), &[]).unwrap();
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert!(a.get_usize("n", 0).is_ok());
        let b = Args::parse(&raw(&["--n", "xyz"]), &[]).unwrap();
        assert!(b.get_usize("n", 1).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&raw(&["--nodes"]), &[]).is_err());
    }

    #[test]
    fn reject_unknown_options() {
        let a = Args::parse(&raw(&["--bogus", "1"]), &[]).unwrap();
        assert!(a.reject_unknown(&["nodes"]).is_err());
        assert!(a.reject_unknown(&["bogus"]).is_ok());
    }
}
