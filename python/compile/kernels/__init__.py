# L1: Pallas kernels for the hot matmuls (projection + Gram) and their
# pure-jnp oracles (ref.py).
