//! Dense linear-algebra substrate.
//!
//! The offline environment has no `nalgebra`/`ndarray`, and the HLO artifacts
//! cannot carry LAPACK custom-calls, so PRONTO ships its own small dense
//! linear algebra: a column-major [`Mat`] type, blocked matmul, Householder
//! QR, and a one-sided Jacobi SVD. These are the same algorithms the L2 JAX
//! graphs use (`python/compile/linalg.py`), which makes the Rust side a
//! numerical oracle for the AOT artifacts.
//!
//! Sizes in PRONTO are modest (d ≲ 150 features, r ≤ 16 components,
//! b ≤ 128 block columns), so clarity and cache-friendliness beat
//! asymptotics here.

mod mat;
mod qr;
mod svd;

pub use mat::{LinalgBacking, Mat};
pub use qr::{householder_qr, thin_qr};
pub use svd::{jacobi_svd, svd_gram_topk, svd_gram_topk_warm, svd_truncated, Svd};

/// Machine-epsilon-scale tolerance used across decomposition tests.
pub const EPS: f64 = 1e-10;

/// Frobenius norm of the difference of two matrices (convenience for tests
/// and convergence checks).
pub fn frob_diff(a: &Mat, b: &Mat) -> f64 {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
    let mut s = 0.0;
    for (x, y) in a.data().iter().zip(b.data().iter()) {
        let d = x - y;
        s += d * d;
    }
    s.sqrt()
}

/// Largest absolute entry of `I - UᵀU`: how far `U`'s columns are from
/// orthonormality.
pub fn orthonormality_error(u: &Mat) -> f64 {
    let g = u.transpose_mul(u);
    let mut worst = 0.0f64;
    for i in 0..g.rows() {
        for j in 0..g.cols() {
            let target = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g.get(i, j) - target).abs());
        }
    }
    worst
}

/// Principal-angle distance between the subspaces spanned by `u1` and `u2`
/// (both assumed orthonormal): ‖U1U1ᵀ − U2U2ᵀ‖₂ approximated through the
/// singular values of U1ᵀU2. Returns a value in [0, 1]; 0 means identical
/// subspaces.
pub fn subspace_distance(u1: &Mat, u2: &Mat) -> f64 {
    assert_eq!(u1.rows(), u2.rows());
    let g = u1.transpose_mul(u2); // r1 x r2
    let svd = jacobi_svd(&g);
    // cos of the largest principal angle is the smallest singular value of
    // U1ᵀU2 (when ranks match); distance = sin(theta_max).
    let r = g.rows().min(g.cols());
    let min_sigma = (0..r).map(|i| svd.sigma[i]).fold(f64::INFINITY, f64::min);
    (1.0 - min_sigma.min(1.0).powi(2)).max(0.0).sqrt()
}
