//! KMeans pre-clustering of VM series with the five Table 2 distances.
//!
//! Table 2 forecasts each VM from "all similar VMs" where similarity comes
//! from KMeans over the VMs' CPU Ready series under different metrics:
//! Euclidean, correlation, STS (short time series / slope), CORT
//! (temporal-correlation-weighted), and ACF (autocorrelation-feature)
//! distances. The "Ordered" row is the non-clustered ordering baseline
//! (nearest VMs by plain distance).
//!
//! Centroids live in plain ℝ^T and are updated as coordinate means; the
//! exotic metrics affect the *assignment* step only — the standard
//! k-means-with-custom-distance construction used in the time-series
//! clustering literature the paper draws on.

use crate::rng::Xoshiro256;

/// Distance metric between two equal-length series.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DistanceKind {
    Euclidean,
    Correlation,
    Sts,
    Cort,
    Acf,
}

impl DistanceKind {
    pub fn name(self) -> &'static str {
        match self {
            DistanceKind::Euclidean => "KM Euclidean",
            DistanceKind::Correlation => "KM Corr",
            DistanceKind::Sts => "KM Sts",
            DistanceKind::Cort => "KM Cort",
            DistanceKind::Acf => "KM Acf",
        }
    }

    pub fn distance(self, a: &[f64], b: &[f64]) -> f64 {
        match self {
            DistanceKind::Euclidean => euclidean(a, b),
            DistanceKind::Correlation => 1.0 - pearson(a, b),
            DistanceKind::Sts => sts_distance(a, b),
            DistanceKind::Cort => cort_distance(a, b),
            DistanceKind::Acf => acf_distance(a, b, 12),
        }
    }
}

fn euclidean(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
}

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - ma) * (y - mb);
        da += (x - ma) * (x - ma);
        db += (y - mb) * (y - mb);
    }
    let denom = (da * db).sqrt();
    if denom < 1e-12 {
        0.0
    } else {
        num / denom
    }
}

/// STS (short time series) distance: L2 between the slope sequences.
pub fn sts_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for t in 1..a.len() {
        let sa = a[t] - a[t - 1];
        let sb = b[t] - b[t - 1];
        s += (sa - sb) * (sa - sb);
    }
    s.sqrt()
}

/// CORT dissimilarity (Chouakria–Douzal): Euclidean distance modulated by
/// the temporal correlation of the first differences,
/// `d = euclid(a, b) · 2 / (1 + exp(k · cort))` with k = 2.
pub fn cort_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for t in 1..a.len() {
        let sa = a[t] - a[t - 1];
        let sb = b[t] - b[t - 1];
        num += sa * sb;
        da += sa * sa;
        db += sb * sb;
    }
    let denom = (da * db).sqrt();
    let cort = if denom < 1e-12 { 0.0 } else { num / denom };
    let k = 2.0;
    euclidean(a, b) * 2.0 / (1.0 + (k * cort).exp())
}

/// Autocorrelation of `xs` at lags 1..=max_lag.
fn acf(xs: &[f64], max_lag: usize) -> Vec<f64> {
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum();
    let mut out = Vec::with_capacity(max_lag);
    for lag in 1..=max_lag.min(n - 1) {
        let mut c = 0.0;
        for t in lag..n {
            c += (xs[t] - mean) * (xs[t - lag] - mean);
        }
        out.push(if var < 1e-12 { 0.0 } else { c / var });
    }
    out
}

/// ACF distance: L2 between autocorrelation vectors.
pub fn acf_distance(a: &[f64], b: &[f64], max_lag: usize) -> f64 {
    let fa = acf(a, max_lag);
    let fb = acf(b, max_lag);
    euclidean(&fa, &fb)
}

/// KMeans over a set of equal-length series with a pluggable distance.
#[derive(Debug, Clone)]
pub struct KMeansSeries {
    pub k: usize,
    pub kind: DistanceKind,
    pub max_iters: usize,
}

impl KMeansSeries {
    pub fn new(k: usize, kind: DistanceKind) -> Self {
        Self { k, kind, max_iters: 50 }
    }

    /// Cluster the series; returns per-series cluster assignments.
    pub fn fit(&self, series: &[Vec<f64>], seed: u64) -> Vec<usize> {
        assert!(!series.is_empty());
        let k = self.k.min(series.len());
        let t = series[0].len();
        assert!(series.iter().all(|s| s.len() == t), "unequal lengths");
        let mut rng = Xoshiro256::seed_from_u64(seed);

        // k-means++-style seeding: first centroid random, rest by farthest
        // distance sampling.
        let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
        centroids.push(series[rng.gen_range(series.len())].clone());
        while centroids.len() < k {
            let dists: Vec<f64> = series
                .iter()
                .map(|s| {
                    centroids
                        .iter()
                        .map(|c| self.kind.distance(s, c))
                        .fold(f64::INFINITY, f64::min)
                        .powi(2)
                })
                .collect();
            let total: f64 = dists.iter().sum();
            if total <= 0.0 {
                centroids.push(series[rng.gen_range(series.len())].clone());
                continue;
            }
            let mut u = rng.next_f64() * total;
            let mut pick = 0;
            for (i, &d) in dists.iter().enumerate() {
                u -= d;
                if u <= 0.0 {
                    pick = i;
                    break;
                }
            }
            centroids.push(series[pick].clone());
        }

        let mut assign = vec![0usize; series.len()];
        for _ in 0..self.max_iters {
            // Assignment.
            let mut changed = false;
            for (i, s) in series.iter().enumerate() {
                let best = (0..k)
                    .min_by(|&a, &b| {
                        self.kind
                            .distance(s, &centroids[a])
                            .partial_cmp(&self.kind.distance(s, &centroids[b]))
                            .unwrap()
                    })
                    .unwrap();
                if assign[i] != best {
                    assign[i] = best;
                    changed = true;
                }
            }
            // Update (coordinate means).
            for (c, centroid) in centroids.iter_mut().enumerate() {
                let members: Vec<&Vec<f64>> = series
                    .iter()
                    .zip(&assign)
                    .filter(|(_, &a)| a == c)
                    .map(|(s, _)| s)
                    .collect();
                if members.is_empty() {
                    continue;
                }
                for j in 0..t {
                    centroid[j] =
                        members.iter().map(|m| m[j]).sum::<f64>() / members.len() as f64;
                }
            }
            if !changed {
                break;
            }
        }
        assign
    }

    /// Indices of series sharing `target`'s cluster (excluding itself).
    pub fn similar_to(&self, series: &[Vec<f64>], target: usize, seed: u64) -> Vec<usize> {
        let assign = self.fit(series, seed);
        let c = assign[target];
        (0..series.len())
            .filter(|&i| i != target && assign[i] == c)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn sine(n: usize, freq: f64, phase: f64, noise: f64, rng: &mut Xoshiro256) -> Vec<f64> {
        (0..n)
            .map(|t| (t as f64 * freq + phase).sin() + noise * rng.normal())
            .collect()
    }

    #[test]
    fn distances_are_zero_on_identical_series() {
        let a = vec![1.0, 2.0, 1.5, 3.0, 2.5];
        for kind in [
            DistanceKind::Euclidean,
            DistanceKind::Sts,
            DistanceKind::Cort,
            DistanceKind::Acf,
        ] {
            assert!(kind.distance(&a, &a) < 1e-9, "{kind:?}");
        }
        // Correlation distance of identical non-constant series is 0.
        assert!(DistanceKind::Correlation.distance(&a, &a) < 1e-9);
    }

    #[test]
    fn correlation_distance_ignores_scale() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b: Vec<f64> = a.iter().map(|x| 100.0 * x + 7.0).collect();
        assert!(DistanceKind::Correlation.distance(&a, &b) < 1e-9);
        assert!(DistanceKind::Euclidean.distance(&a, &b) > 100.0);
    }

    #[test]
    fn cort_penalizes_opposite_trends() {
        let up: Vec<f64> = (0..20).map(|t| t as f64).collect();
        let down: Vec<f64> = (0..20).map(|t| 19.0 - t as f64).collect();
        let same_e = DistanceKind::Euclidean.distance(&up, &down);
        let cort = cort_distance(&up, &down);
        // CORT multiplies the euclidean distance by ~2/(1+e^{-2}) ≈ 1.76
        // for perfectly anti-correlated slopes.
        assert!(cort > same_e * 1.5, "cort={cort} e={same_e}");
    }

    #[test]
    fn kmeans_separates_frequencies() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut series = Vec::new();
        for i in 0..12 {
            let freq = if i < 6 { 0.1 } else { 0.9 };
            series.push(sine(200, freq, 0.0, 0.05, &mut rng));
        }
        let km = KMeansSeries::new(2, DistanceKind::Acf);
        let assign = km.fit(&series, 3);
        // All of the first six share a cluster; all of the last six the other.
        let c0 = assign[0];
        assert!(assign[..6].iter().all(|&a| a == c0));
        assert!(assign[6..].iter().all(|&a| a != c0));
    }

    #[test]
    fn similar_to_excludes_self() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let series: Vec<Vec<f64>> =
            (0..8).map(|_| sine(100, 0.3, 0.0, 0.1, &mut rng)).collect();
        let km = KMeansSeries::new(2, DistanceKind::Euclidean);
        let sim = km.similar_to(&series, 3, 1);
        assert!(!sim.contains(&3));
    }

    #[test]
    fn acf_of_periodic_series_peaks_at_period() {
        let xs: Vec<f64> = (0..100).map(|t| ((t % 10) as f64)).collect();
        let f = acf(&xs, 20);
        // lag 10 autocorrelation should dominate lag 5.
        assert!(f[9] > f[4], "acf10={} acf5={}", f[9], f[4]);
    }
}
