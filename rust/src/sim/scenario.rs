//! Scenario descriptions: what the cluster experiences during a run.
//!
//! A [`Scenario`] composes an arrival pattern (Poisson, bursty/MMPP-2,
//! diurnal), an optional churn model (nodes leaving and rejoining
//! mid-run, §5.2's transient nodes), and a federation link with a
//! configurable push-latency distribution. The named catalog makes the
//! paper's evaluation runs (steady Poisson arrivals, zero latency) just
//! two points in a much larger space; custom scenarios load from the same
//! TOML subset the main config uses (`pronto sim --scenario file.toml`).

use crate::config::{parse_toml, TomlValue};
use crate::federation::LatencyModel;
use crate::rng::Xoshiro256;
use crate::scheduler::QueuePolicy;
use crate::telemetry::VmTrace;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// How the dispatcher picks the *candidate set* of nodes an arriving job
/// probes (how many offers go out and to whom). What happens with the
/// probe answers is the orthogonal [`DispatchPolicy`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbePolicy {
    /// Probe one uniformly random node (Sparrow-style single probe).
    RandomProbe,
    /// Probe `k` distinct random nodes.
    PowerOfK(usize),
    /// Round-robin over nodes.
    RoundRobin,
}

/// How the dispatcher scores the probed candidates. Every admission offer
/// returns a structured [`crate::scheduler::AdmissionProbe`] (signal,
/// free slots, queue depth, queue-delay EWMA); the policy decides how much
/// of it to look at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// The paper's dispatch: take the first probed node whose rejection
    /// signal is clear, ignoring congestion. Preserves the pre-probe
    /// engine behaviour bit-for-bit.
    SignalOnly,
    /// Among signal-clear candidates, pick the least congested: shallowest
    /// wait queue, then shortest queue-delay EWMA, then most free slots
    /// (first probed wins remaining ties). The FedQueue-style fix for the
    /// "clear signal, deep queue" blind spot.
    QueueAware,
    /// Among signal-clear candidates, pick the one with the most free
    /// slots (then the shallowest queue) — classic least-loaded-of-k,
    /// the natural choice on heterogeneous fleets.
    LeastLoaded,
}

/// A trace-driven arrival sequence: exact per-step job counts, typically
/// read back from a [`VmTrace`]-format CSV (`timestep,<metric...>`; the
/// column named `arrivals` — or the first column — holds the counts).
/// Multiple per-VM CSVs in a directory merge by summing counts per step,
/// so a fleet's arrival sequences replay as one cluster-level stream.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplaySchedule {
    counts: Vec<u32>,
    source: String,
}

impl ReplaySchedule {
    /// Schedule from explicit per-step counts.
    pub fn from_counts(counts: Vec<u32>, source: impl Into<String>) -> Self {
        Self { counts, source: source.into() }
    }

    /// Extract per-step arrival counts from a trace: the metric named
    /// `metric` if given, else the `arrivals` column, else column 0.
    /// Values are rounded and clamped at zero.
    pub fn from_vm_trace(tr: &VmTrace, metric: Option<&str>) -> Result<Self> {
        let idx = match metric {
            Some(name) => tr
                .metric_index(name)
                .ok_or_else(|| anyhow::anyhow!("replay trace has no metric '{name}'"))?,
            None => tr.metric_index("arrivals").unwrap_or(0),
        };
        let counts = (0..tr.len())
            .map(|t| tr.features(t)[idx].round().max(0.0) as u32)
            .collect();
        Ok(Self { counts, source: format!("vm{}", tr.vm_id) })
    }

    /// Load from a CSV file, or merge every `*.csv` in a directory
    /// (per-VM arrival sequences summed per step).
    pub fn from_path(path: &Path, metric: Option<&str>) -> Result<Self> {
        if path.is_dir() {
            let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(path)
                .with_context(|| format!("reading {}", path.display()))?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.extension().map(|x| x == "csv").unwrap_or(false))
                .collect();
            files.sort();
            if files.is_empty() {
                bail!("no .csv traces in {}", path.display());
            }
            let mut counts: Vec<u32> = Vec::new();
            for (i, f) in files.iter().enumerate() {
                let tr = VmTrace::read_csv(f, i, 0)?;
                let one = Self::from_vm_trace(&tr, metric)?;
                if one.counts.len() > counts.len() {
                    counts.resize(one.counts.len(), 0);
                }
                for (acc, c) in counts.iter_mut().zip(&one.counts) {
                    *acc += c;
                }
            }
            Ok(Self { counts, source: path.display().to_string() })
        } else {
            let tr = VmTrace::read_csv(path, 0, 0)
                .with_context(|| format!("reading replay trace {}", path.display()))?;
            let mut s = Self::from_vm_trace(&tr, metric)?;
            s.source = path.display().to_string();
            Ok(s)
        }
    }

    /// Built-in deterministic demo schedule for the `replay` catalog entry
    /// (no external file needed): a sparse base stream with periodic
    /// 3-job batches, long-run rate ≈ 0.2/step.
    pub fn demo(steps: usize) -> Self {
        let counts = (0..steps)
            .map(|t| {
                if t % 50 == 0 {
                    3
                } else if t % 7 == 0 {
                    1
                } else {
                    0
                }
            })
            .collect();
        Self { counts, source: "demo".into() }
    }

    /// Arrival count at `step` (0 past the end of the schedule).
    pub fn count_at(&self, step: usize) -> u32 {
        self.counts.get(step).copied().unwrap_or(0)
    }

    pub fn len(&self) -> usize {
        self.counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Total jobs in the schedule.
    pub fn total(&self) -> usize {
        self.counts.iter().map(|&c| c as usize).sum()
    }

    pub fn source(&self) -> &str {
        &self.source
    }
}

/// Job arrival process, parameterized per telemetry step.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalPattern {
    /// Homogeneous Poisson stream.
    Poisson { rate: f64 },
    /// Two-state Markov-modulated Poisson process: quiet periods at
    /// `base_rate` punctuated by bursts at `burst_rate`; both regime
    /// durations are geometric with the given means (in steps).
    Bursty {
        base_rate: f64,
        burst_rate: f64,
        mean_burst_len: f64,
        mean_gap_len: f64,
    },
    /// Sinusoidal day/night modulation:
    /// `rate(t) = base_rate * (1 + amplitude * sin(2πt / period))`,
    /// clamped at 0.
    Diurnal {
        base_rate: f64,
        amplitude: f64,
        period_steps: usize,
    },
    /// Trace-driven replay: the engine injects *exactly*
    /// `schedule.count_at(step)` jobs at each step — no randomness.
    Replay { schedule: Arc<ReplaySchedule> },
}

impl ArrivalPattern {
    /// Expected rate at `step` given the current burst regime.
    pub fn rate_at(&self, step: usize, burst_on: bool) -> f64 {
        match self {
            ArrivalPattern::Poisson { rate } => *rate,
            ArrivalPattern::Bursty { base_rate, burst_rate, .. } => {
                if burst_on {
                    *burst_rate
                } else {
                    *base_rate
                }
            }
            ArrivalPattern::Diurnal { base_rate, amplitude, period_steps } => {
                let phase =
                    step as f64 / period_steps.max(1) as f64 * std::f64::consts::TAU;
                (base_rate * (1.0 + amplitude * phase.sin())).max(0.0)
            }
            ArrivalPattern::Replay { schedule } => schedule.count_at(step) as f64,
        }
    }

    /// Long-run average rate (used for queue pre-sizing).
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalPattern::Poisson { rate } => *rate,
            ArrivalPattern::Bursty {
                base_rate,
                burst_rate,
                mean_burst_len,
                mean_gap_len,
            } => {
                let total = (mean_burst_len + mean_gap_len).max(1e-9);
                (burst_rate * mean_burst_len + base_rate * mean_gap_len) / total
            }
            ArrivalPattern::Diurnal { base_rate, .. } => *base_rate,
            ArrivalPattern::Replay { schedule } => {
                schedule.total() as f64 / schedule.len().max(1) as f64
            }
        }
    }
}

/// Node churn: memoryless leave hazard with optional rejoin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnModel {
    /// Per-node per-step probability of leaving.
    pub leave_hazard: f64,
    /// Mean rejoin delay in steps (exponential); `<= 0` means nodes never
    /// come back.
    pub rejoin_delay_mean: f64,
    /// Never drain the pool below this many alive nodes.
    pub min_alive: usize,
}

/// Fault-injection layer: correlated failures the benign catalog never
/// exercises. Every component is inert at its zero default, draws from
/// its own dedicated RNG stream in the engine (`rack-outage`,
/// `partition`, `straggler`, `antagonist` in [`crate::rng::streams`]),
/// and reports through keys that appear in the JSON only when the
/// component is active — legacy reports stay byte-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureModel {
    /// Nodes per rack: node ids `[r·rack_size, (r+1)·rack_size)` form
    /// rack `r`. Rack outages take the whole group down at once.
    pub rack_size: usize,
    /// Per-rack per-step probability of a correlated outage (0 = off).
    pub rack_outage_hazard: f64,
    /// Mean outage length in steps (exponential); the whole rack rejoins
    /// together when it elapses.
    pub rack_outage_duration_mean: f64,
    /// Rack outages never drain the fleet below this many alive nodes.
    pub min_alive: usize,
    /// Per-step probability that a federation network partition opens
    /// (0 = off). Requires federation to be enabled.
    pub partition_hazard: f64,
    /// Mean heal time in steps (exponential).
    pub partition_duration_mean: f64,
    /// Fraction of the fleet's leaves cut off per partition (at least 1).
    pub partition_fraction: f64,
    /// `true`: pushes from partitioned leaves queue at the cut and replay
    /// **stale** on heal (the §5.2 stale-merge path). `false`: they are
    /// dropped and counted (`federation_partition_drops`).
    pub partition_queue: bool,
    /// Fraction of nodes designated stragglers at engine init (0 = off).
    pub straggler_fraction: f64,
    /// Multiplier on a straggler's sampled federation push latency
    /// (needs a non-instant latency model to have any effect).
    pub straggler_delay_multiplier: f64,
    /// A straggler's published rejection signal lags its computed one by
    /// this many telemetry steps (delayed observe columns).
    pub straggler_observe_lag: usize,
    /// Poisson rate of a second, antagonist tenant's arrivals (0 = off).
    /// All antagonist draws come from a dedicated stream, so enabling the
    /// tenant never shifts the primary workload.
    pub antagonist_rate: f64,
    /// Priority class of antagonist jobs (clamped to the capacity model's
    /// `priority_levels`).
    pub antagonist_priority: u8,
}

impl Default for FailureModel {
    fn default() -> Self {
        Self {
            rack_size: 8,
            rack_outage_hazard: 0.0,
            rack_outage_duration_mean: 60.0,
            min_alive: 1,
            partition_hazard: 0.0,
            partition_duration_mean: 40.0,
            partition_fraction: 0.25,
            partition_queue: true,
            straggler_fraction: 0.0,
            straggler_delay_multiplier: 4.0,
            straggler_observe_lag: 2,
            antagonist_rate: 0.0,
            antagonist_priority: 0,
        }
    }
}

impl FailureModel {
    /// Does any rack ever go down?
    pub fn rack_outages_enabled(&self) -> bool {
        self.rack_outage_hazard > 0.0
    }

    /// Do partitions ever open?
    pub fn partitions_enabled(&self) -> bool {
        self.partition_hazard > 0.0
    }

    /// Are any nodes designated stragglers?
    pub fn stragglers_enabled(&self) -> bool {
        self.straggler_fraction > 0.0
    }

    /// Does the antagonist tenant submit jobs?
    pub fn antagonist_enabled(&self) -> bool {
        self.antagonist_rate > 0.0
    }

    /// Does the model induce node churn (leave/rejoin) on its own?
    pub fn induces_churn(&self) -> bool {
        self.rack_outages_enabled()
    }
}

/// One class of hosts in a heterogeneous fleet: a slot budget and the
/// relative weight with which nodes are assigned to the class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostClass {
    /// Slot budget of hosts in this class.
    pub slots: u32,
    /// Relative assignment weight (need not sum to 1 across classes).
    pub weight: f64,
}

/// Host-level capacity: finite slots per node, a bounded wait queue, and
/// the preemption/migration behaviour of displaced jobs. Absent (`None`
/// on the scenario), the engine runs the legacy admission-only model —
/// accepted jobs are free and nothing ever queues.
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityModel {
    /// Base slot budget per node (the budget of every node when
    /// `host_classes` is empty).
    pub slots_per_node: u32,
    /// Effective budget while the node's rejection signal is raised:
    /// running jobs above it are preempted at the telemetry tick (lowest
    /// priority first, newest first within a class) and re-offered to
    /// peers. Set equal to `slots_per_node` to disable pressure
    /// preemption fleet-wide; on heterogeneous fleets the budget is
    /// clamped to each node's own slots.
    pub contended_slots: u32,
    /// Bounded wait-queue length per node (0 = no queue: start-or-drop).
    pub queue_capacity: usize,
    /// Per-job slot demand is uniform on `{1, …, max_job_slots}` (clamped
    /// at hand-off to the placed host's budget so a small host can always
    /// eventually start the job).
    pub max_job_slots: u32,
    /// How the wait queue drains when slots free up.
    pub queue_policy: QueuePolicy,
    /// Re-placement attempts a displaced job gets before it counts as
    /// lost (`jobs_displaced`); 0 = preemption always loses the job.
    pub migration_limit: u32,
    /// Scheduling classes: each job draws a priority uniform on
    /// `{0, …, priority_levels-1}` (higher serves first). 1 = the legacy
    /// single-class fleet.
    pub priority_levels: u8,
    /// Per-job completion deadline in steps after arrival; `None`
    /// disables SLO accounting.
    pub slo_steps: Option<u32>,
    /// Heterogeneous fleet: nodes draw their budget from these classes
    /// (weighted, from a dedicated RNG stream). Empty = homogeneous at
    /// `slots_per_node`.
    pub host_classes: Vec<HostClass>,
}

impl Default for CapacityModel {
    fn default() -> Self {
        Self {
            slots_per_node: 4,
            contended_slots: 4,
            queue_capacity: 8,
            max_job_slots: 1,
            queue_policy: QueuePolicy::Fifo,
            migration_limit: 1,
            priority_levels: 1,
            slo_steps: None,
            host_classes: Vec::new(),
        }
    }
}

impl CapacityModel {
    /// Slot budget for one node: a weighted class draw on heterogeneous
    /// fleets, the homogeneous base otherwise (no randomness consumed).
    pub fn draw_slots(&self, rng: &mut Xoshiro256) -> u32 {
        if self.host_classes.is_empty() {
            return self.slots_per_node;
        }
        let total: f64 = self.host_classes.iter().map(|c| c.weight).sum();
        let mut x = rng.next_f64() * total;
        for c in &self.host_classes {
            if x < c.weight {
                return c.slots;
            }
            x -= c.weight;
        }
        // Floating-point edge: the draw landed exactly on the total.
        self.host_classes[self.host_classes.len() - 1].slots
    }

    /// Largest budget any node can be assigned.
    pub fn max_host_slots(&self) -> u32 {
        self.host_classes
            .iter()
            .map(|c| c.slots)
            .max()
            .unwrap_or(self.slots_per_node)
    }

    /// Is pressure preemption configured at all?
    pub fn pressure_enabled(&self) -> bool {
        self.contended_slots < self.slots_per_node
    }

    /// Effective budget of a `host_slots`-sized node while its rejection
    /// signal is raised.
    pub fn contended_budget(&self, host_slots: u32) -> u32 {
        if self.pressure_enabled() {
            self.contended_slots.min(host_slots)
        } else {
            host_slots
        }
    }
}

/// The federation link the engine drives during a run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FederationSpec {
    pub enabled: bool,
    /// Aggregator fanout.
    pub fanout: usize,
    /// Merge rank at aggregators.
    pub rank: usize,
    /// ε threshold of the push gate.
    pub epsilon: f64,
    /// Leaves offer their iterate every this many steps.
    pub push_every: usize,
    /// Push delivery latency distribution.
    pub latency: LatencyModel,
    /// Rejoining nodes pull the merged global view to re-seed (§5.2).
    pub pull_on_join: bool,
    /// Forgetting factor applied to the global side of a join pull.
    pub pull_forget: f64,
}

impl Default for FederationSpec {
    fn default() -> Self {
        Self {
            enabled: false,
            fanout: 8,
            rank: 4,
            epsilon: 0.25,
            push_every: 64,
            latency: LatencyModel::None,
            pull_on_join: true,
            pull_forget: 0.5,
        }
    }
}

/// A complete description of one simulated run.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    /// Number of data-center nodes.
    pub nodes: usize,
    /// Run length in telemetry steps (clamped to the trace length).
    pub steps: usize,
    /// Master seed; all engine RNG streams derive from it.
    pub seed: u64,
    pub arrivals: ArrivalPattern,
    /// Candidate selection: how many nodes an arriving job probes.
    pub probe: ProbePolicy,
    /// Candidate scoring: what the dispatcher does with the probe answers.
    pub dispatch: DispatchPolicy,
    /// Log-normal job duration parameters (steps).
    pub duration_mu: f64,
    pub duration_sigma: f64,
    /// CPU Ready level marking degraded service for scoring.
    pub ready_threshold: f64,
    /// Horizon after acceptance scored for degradation (steps).
    pub score_window: usize,
    /// Worker threads for the per-tick observe loop. 1 (the default)
    /// runs the exact sequential code path; any width produces
    /// byte-identical reports (per-node state is sharded disjointly and
    /// merged in node-id order), so this knob trades wall time only.
    pub threads: usize,
    pub churn: Option<ChurnModel>,
    pub federation: FederationSpec,
    /// Host capacity model; `None` = legacy admission-only simulation.
    pub capacity: Option<CapacityModel>,
    /// Fault-injection layer; `None` = the benign legacy catalog.
    pub failures: Option<FailureModel>,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            name: "baseline-poisson".to_string(),
            nodes: 16,
            steps: 2_000,
            seed: 2021,
            arrivals: ArrivalPattern::Poisson { rate: 0.3 },
            probe: ProbePolicy::PowerOfK(2),
            dispatch: DispatchPolicy::SignalOnly,
            duration_mu: 3.0,
            duration_sigma: 0.8,
            ready_threshold: 1000.0,
            score_window: 5,
            threads: 1,
            churn: None,
            federation: FederationSpec::default(),
            capacity: None,
            failures: None,
        }
    }
}

/// Names in the built-in catalog, in display order.
pub const CATALOG: &[&str] = &[
    "baseline-poisson",
    "bursty",
    "diurnal",
    "churn",
    "latency",
    "churn-latency",
    "capacity",
    "preemption",
    "replay",
    "queue-aware",
    "priority",
    "hetero",
    "large-fleet",
    "flash-crowd",
    "rack-outage",
    "partition",
    "straggler",
    "antagonist",
];

impl Scenario {
    /// Look up a named scenario from the built-in catalog.
    pub fn named(name: &str) -> Option<Scenario> {
        let base = Scenario::default();
        let s = match name {
            // The paper's setting: steady Poisson arrivals, full
            // membership, instant federation (fig. 1 / fig. 7 conditions).
            "baseline-poisson" => Scenario { ..base },
            // Flash-crowd arrivals: long quiet stretches, 10× bursts.
            "bursty" => Scenario {
                name: name.into(),
                arrivals: ArrivalPattern::Bursty {
                    base_rate: 0.15,
                    burst_rate: 1.5,
                    mean_burst_len: 40.0,
                    mean_gap_len: 200.0,
                },
                ..base
            },
            // Day/night swing over a compressed 4-hour "day".
            "diurnal" => Scenario {
                name: name.into(),
                arrivals: ArrivalPattern::Diurnal {
                    base_rate: 0.3,
                    amplitude: 0.8,
                    period_steps: 720,
                },
                ..base
            },
            // Nodes leave and rejoin mid-run; rejoining nodes pull the
            // global view (arXiv:2101.06139's join/leave regime).
            "churn" => Scenario {
                name: name.into(),
                churn: Some(ChurnModel {
                    leave_hazard: 0.0008,
                    rejoin_delay_mean: 120.0,
                    min_alive: 4,
                }),
                federation: FederationSpec { enabled: true, ..Default::default() },
                ..base
            },
            // Federation pushes cross a WAN: exponential delay, mean
            // 8 steps (~2.7 min) — iterates merge stale.
            "latency" => Scenario {
                name: name.into(),
                federation: FederationSpec {
                    enabled: true,
                    latency: LatencyModel::Exponential { mean_steps: 8.0 },
                    ..Default::default()
                },
                ..base
            },
            // Finite hosts under sustained overload: 1.3 jobs/step of
            // ~20-step jobs against 16×2 slots (~1.1× oversubscribed) —
            // queues build, the bounded queue drops the excess.
            "capacity" => Scenario {
                name: name.into(),
                arrivals: ArrivalPattern::Poisson { rate: 1.3 },
                capacity: Some(CapacityModel {
                    slots_per_node: 2,
                    contended_slots: 2,
                    queue_capacity: 4,
                    max_job_slots: 1,
                    queue_policy: QueuePolicy::Fifo,
                    migration_limit: 0,
                    ..CapacityModel::default()
                }),
                ..base
            },
            // The `capacity` overload with probe-scored placement: the
            // dispatcher joins the shallower queue of its two probes
            // instead of the first signal-clear one (power-of-two-choices
            // over the structured AdmissionProbe).
            "queue-aware" => Scenario {
                name: name.into(),
                arrivals: ArrivalPattern::Poisson { rate: 1.3 },
                dispatch: DispatchPolicy::QueueAware,
                capacity: Some(CapacityModel {
                    slots_per_node: 2,
                    contended_slots: 2,
                    queue_capacity: 4,
                    max_job_slots: 1,
                    queue_policy: QueuePolicy::Fifo,
                    migration_limit: 0,
                    ..CapacityModel::default()
                }),
                ..base
            },
            // Three scheduling classes under sustained load with a
            // completion SLO: queues serve strictly by priority, pressure
            // sheds the lowest class first, and the report scores SLO
            // attainment plus per-class queue delay.
            "priority" => Scenario {
                name: name.into(),
                arrivals: ArrivalPattern::Poisson { rate: 1.0 },
                dispatch: DispatchPolicy::QueueAware,
                capacity: Some(CapacityModel {
                    slots_per_node: 2,
                    contended_slots: 2,
                    queue_capacity: 8,
                    max_job_slots: 1,
                    queue_policy: QueuePolicy::Fifo,
                    migration_limit: 0,
                    priority_levels: 3,
                    slo_steps: Some(30),
                    ..CapacityModel::default()
                }),
                ..base
            },
            // Heterogeneous fleet: small/medium/large hosts (1/2/4 slots,
            // mean 2.25), least-loaded placement, smallest-first queues.
            // Oversized draws clamp to the placed host's budget.
            "hetero" => Scenario {
                name: name.into(),
                arrivals: ArrivalPattern::Poisson { rate: 1.3 },
                dispatch: DispatchPolicy::LeastLoaded,
                capacity: Some(CapacityModel {
                    slots_per_node: 2,
                    contended_slots: 2,
                    queue_capacity: 4,
                    max_job_slots: 2,
                    queue_policy: QueuePolicy::SmallestFirst,
                    migration_limit: 0,
                    host_classes: vec![
                        HostClass { slots: 1, weight: 0.25 },
                        HostClass { slots: 2, weight: 0.5 },
                        HostClass { slots: 4, weight: 0.25 },
                    ],
                    ..CapacityModel::default()
                }),
                ..base
            },
            // The horizontal-scalability regime: a 2 000-node fleet under
            // steady Poisson load at ~70 % slot utilization. Sized for the
            // streaming trace source (`pronto sim` auto-streams at this
            // fleet size; `pronto bench engine` sweeps it at 100/1k/5k
            // nodes).
            "large-fleet" => Scenario {
                name: name.into(),
                nodes: 2_000,
                arrivals: ArrivalPattern::Poisson { rate: 100.0 },
                capacity: Some(CapacityModel {
                    slots_per_node: 2,
                    contended_slots: 2,
                    queue_capacity: 4,
                    max_job_slots: 1,
                    queue_policy: QueuePolicy::Fifo,
                    migration_limit: 0,
                    ..CapacityModel::default()
                }),
                ..base
            },
            // A 1 000-node fleet hit by MMPP burst storms: ~28 % baseline
            // load punctuated by ~25-step storms whose offered load far
            // exceeds the whole fleet — queues flood, bounded queues drop,
            // queue-aware dispatch spreads the blast.
            "flash-crowd" => Scenario {
                name: name.into(),
                nodes: 1_000,
                arrivals: ArrivalPattern::Bursty {
                    base_rate: 20.0,
                    burst_rate: 400.0,
                    mean_burst_len: 25.0,
                    mean_gap_len: 250.0,
                },
                dispatch: DispatchPolicy::QueueAware,
                capacity: Some(CapacityModel {
                    slots_per_node: 2,
                    contended_slots: 2,
                    queue_capacity: 4,
                    max_job_slots: 1,
                    queue_policy: QueuePolicy::Fifo,
                    migration_limit: 0,
                    ..CapacityModel::default()
                }),
                ..base
            },
            // Displacement in both flavours: departing nodes evacuate
            // their jobs, and contended nodes (rejection signal raised)
            // shed down to one slot; displaced jobs migrate to peers via
            // each peer's admission signal (up to 2 hops).
            "preemption" => Scenario {
                name: name.into(),
                arrivals: ArrivalPattern::Poisson { rate: 0.5 },
                capacity: Some(CapacityModel {
                    slots_per_node: 4,
                    contended_slots: 1,
                    queue_capacity: 8,
                    max_job_slots: 2,
                    queue_policy: QueuePolicy::SmallestFirst,
                    migration_limit: 2,
                    ..CapacityModel::default()
                }),
                churn: Some(ChurnModel {
                    leave_hazard: 0.002,
                    rejoin_delay_mean: 100.0,
                    min_alive: 4,
                }),
                federation: FederationSpec { enabled: true, ..Default::default() },
                ..base
            },
            // Trace-driven arrivals: the built-in demo schedule (periodic
            // 3-job batches over a sparse base stream); real traces load
            // with `--replay <csv>` or `[arrivals] pattern = "replay"`.
            "replay" => Scenario {
                name: name.into(),
                arrivals: ArrivalPattern::Replay {
                    schedule: Arc::new(ReplaySchedule::demo(base.steps)),
                },
                ..base
            },
            // Both stressors at once.
            "churn-latency" => Scenario {
                name: name.into(),
                churn: Some(ChurnModel {
                    leave_hazard: 0.0008,
                    rejoin_delay_mean: 120.0,
                    min_alive: 4,
                }),
                federation: FederationSpec {
                    enabled: true,
                    latency: LatencyModel::Exponential { mean_steps: 8.0 },
                    ..Default::default()
                },
                ..base
            },
            // Correlated whole-rack outages: racks of 4 hosts fail and
            // rejoin together, evacuating their running sets and wait
            // queues through the migration path. The ledger-conservation
            // sweep drives this entry.
            "rack-outage" => Scenario {
                name: name.into(),
                nodes: 24,
                arrivals: ArrivalPattern::Poisson { rate: 0.5 },
                capacity: Some(CapacityModel {
                    slots_per_node: 4,
                    contended_slots: 4,
                    queue_capacity: 8,
                    max_job_slots: 2,
                    queue_policy: QueuePolicy::Fifo,
                    migration_limit: 2,
                    ..CapacityModel::default()
                }),
                federation: FederationSpec { enabled: true, ..Default::default() },
                // min_alive 4 (not higher): the catalog smoke runs every
                // entry at --nodes 6, and validation requires the floor
                // to sit strictly below the fleet size.
                failures: Some(FailureModel {
                    rack_size: 4,
                    rack_outage_hazard: 0.002,
                    rack_outage_duration_mean: 60.0,
                    min_alive: 4,
                    ..FailureModel::default()
                }),
                ..base
            },
            // Federation-tree network partitions: a quarter of the leaves
            // lose their uplink, their pushes queue at the cut, and heal
            // replays them *stale* — the §5.2 stale-merge path under
            // asynchrony the paper scopes out.
            "partition" => Scenario {
                name: name.into(),
                federation: FederationSpec {
                    enabled: true,
                    latency: LatencyModel::Exponential { mean_steps: 2.0 },
                    ..Default::default()
                },
                failures: Some(FailureModel {
                    partition_hazard: 0.004,
                    partition_duration_mean: 40.0,
                    partition_fraction: 0.25,
                    partition_queue: true,
                    ..FailureModel::default()
                }),
                ..base
            },
            // Straggler nodes: a fifth of the fleet pushes its iterate 8×
            // slower than the WAN baseline and publishes a rejection
            // signal 3 steps stale — the dispatcher steers by telemetry
            // that lags the host's real state.
            "straggler" => Scenario {
                name: name.into(),
                federation: FederationSpec {
                    enabled: true,
                    latency: LatencyModel::Exponential { mean_steps: 2.0 },
                    ..Default::default()
                },
                failures: Some(FailureModel {
                    straggler_fraction: 0.2,
                    straggler_delay_multiplier: 8.0,
                    straggler_observe_lag: 3,
                    ..FailureModel::default()
                }),
                ..base
            },
            // Antagonist tenant: a second arrival stream at high priority
            // thrashes admission against the primary workload's SLO. The
            // report splits attainment and rejections per tenant.
            "antagonist" => Scenario {
                name: name.into(),
                arrivals: ArrivalPattern::Poisson { rate: 0.7 },
                dispatch: DispatchPolicy::QueueAware,
                capacity: Some(CapacityModel {
                    slots_per_node: 2,
                    contended_slots: 2,
                    queue_capacity: 8,
                    max_job_slots: 1,
                    queue_policy: QueuePolicy::Fifo,
                    migration_limit: 0,
                    priority_levels: 3,
                    slo_steps: Some(30),
                    ..CapacityModel::default()
                }),
                failures: Some(FailureModel {
                    antagonist_rate: 0.8,
                    antagonist_priority: 2,
                    ..FailureModel::default()
                }),
                ..base
            },
            _ => return None,
        };
        Some(s)
    }

    /// Does the scenario ever take nodes down — via the churn model or
    /// via failure-induced outages? Gates the rejoin policy factory in
    /// the CLI (a restarted machine loses its in-memory state).
    pub fn has_node_churn(&self) -> bool {
        self.churn.is_some() || self.failures.is_some_and(|f| f.induces_churn())
    }

    /// Resolve a CLI `--scenario` argument: a catalog name, or a path to a
    /// TOML file. (`"none"` is handled by the CLI before resolution — it
    /// selects the fixed-step facade.)
    pub fn resolve(spec: &str) -> Result<Scenario> {
        if let Some(s) = Scenario::named(spec) {
            return Ok(s);
        }
        let path = Path::new(spec);
        if path.exists() {
            return Scenario::from_toml_file(path);
        }
        bail!(
            "unknown scenario '{spec}' (catalog: {}; or pass a .toml path)",
            CATALOG.join(", ")
        );
    }

    /// Load from a TOML file.
    pub fn from_toml_file(path: &Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {}", path.display()))?;
        Scenario::from_toml(&text)
            .with_context(|| format!("parsing scenario {}", path.display()))
    }

    /// Parse from TOML text. Sections: `[scenario]`, `[arrivals]`,
    /// `[capacity]`, `[churn]`, `[federation]`; every key optional,
    /// unknown keys rejected.
    pub fn from_toml(text: &str) -> Result<Scenario> {
        let doc = parse_toml(text).map_err(|e| anyhow::anyhow!("scenario: {e}"))?;
        let mut s = Scenario { name: "custom".into(), ..Default::default() };
        // Arrival-pattern fields are collected across keys, then assembled.
        let mut pattern = "poisson".to_string();
        let mut rate = 0.3f64;
        let mut burst_rate = 1.5f64;
        let mut mean_burst_len = 40.0f64;
        let mut mean_gap_len = 200.0f64;
        let mut amplitude = 0.8f64;
        let mut period_steps = 720usize;
        // Replay arrivals: path + optional metric column.
        let mut replay_path: Option<String> = None;
        let mut replay_metric: Option<String> = None;
        // Churn assembled likewise; presence of the section enables it.
        let mut churn_seen = false;
        let mut churn = ChurnModel { leave_hazard: 0.001, rejoin_delay_mean: 120.0, min_alive: 1 };
        // Capacity assembled likewise; presence of the section enables it.
        // `contended_slots` defaults to the slot budget (no pressure
        // preemption) unless set explicitly.
        let mut capacity_seen = false;
        let mut capacity = CapacityModel::default();
        let mut contended_set = false;
        let mut queue_policy = "fifo".to_string();
        // Heterogeneous classes arrive as parallel arrays (the TOML subset
        // has no table arrays): slots are required, weights default equal.
        let mut host_class_slots: Option<Vec<f64>> = None;
        let mut host_class_weights: Option<Vec<f64>> = None;
        // Failure model assembled likewise; presence of the section
        // enables it (inert unless a hazard/rate/fraction is raised).
        let mut failures_seen = false;
        let mut failures = FailureModel::default();
        // Federation latency fields. Options so a parameter without the
        // selector (or vice versa) can be detected instead of silently
        // degenerating to instant delivery.
        let mut latency_kind: Option<String> = None;
        let mut latency_mean: Option<f64> = None;
        let mut latency_lo: Option<f64> = None;
        let mut latency_hi: Option<f64> = None;
        let mut probe_k = 2usize;
        let mut probe = "power-of-k".to_string();
        let mut probe_set = false;
        let mut dispatch = "signal-only".to_string();

        for (section, entries) in &doc {
            for (key, v) in entries {
                let num = || -> Result<f64> {
                    v.as_f64()
                        .ok_or_else(|| anyhow::anyhow!("{section}.{key}: expected number"))
                };
                let uint = || -> Result<usize> { Ok(num()? as usize) };
                let boolean = || -> Result<bool> {
                    v.as_bool()
                        .ok_or_else(|| anyhow::anyhow!("{section}.{key}: expected bool"))
                };
                let string = || -> Result<String> {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow::anyhow!("{section}.{key}: expected string"))
                };
                let num_array = || -> Result<Vec<f64>> {
                    match v {
                        TomlValue::Array(items) => items
                            .iter()
                            .map(|x| {
                                x.as_f64().ok_or_else(|| {
                                    anyhow::anyhow!("{section}.{key}: expected numbers")
                                })
                            })
                            .collect(),
                        _ => bail!("{section}.{key}: expected an array of numbers"),
                    }
                };
                match (section.as_str(), key.as_str()) {
                    ("scenario", "name") => s.name = string()?,
                    ("scenario", "nodes") => s.nodes = uint()?,
                    ("scenario", "steps") => s.steps = uint()?,
                    ("scenario", "seed") => s.seed = num()? as u64,
                    ("scenario", "probe") => {
                        probe_set = true;
                        probe = string()?;
                    }
                    ("scenario", "dispatch") => dispatch = string()?,
                    ("scenario", "probe_k") => probe_k = uint()?,
                    ("scenario", "duration_mu") => s.duration_mu = num()?,
                    ("scenario", "duration_sigma") => s.duration_sigma = num()?,
                    ("scenario", "ready_threshold") => s.ready_threshold = num()?,
                    ("scenario", "score_window") => s.score_window = uint()?,
                    ("scenario", "threads") => s.threads = uint()?,

                    ("arrivals", "pattern") => pattern = string()?,
                    ("arrivals", "rate") => rate = num()?,
                    ("arrivals", "burst_rate") => burst_rate = num()?,
                    ("arrivals", "mean_burst_len") => mean_burst_len = num()?,
                    ("arrivals", "mean_gap_len") => mean_gap_len = num()?,
                    ("arrivals", "amplitude") => amplitude = num()?,
                    ("arrivals", "period_steps") => period_steps = uint()?,
                    ("arrivals", "replay") => replay_path = Some(string()?),
                    ("arrivals", "replay_metric") => replay_metric = Some(string()?),

                    ("capacity", "slots_per_node") => {
                        capacity_seen = true;
                        capacity.slots_per_node = uint()? as u32;
                    }
                    ("capacity", "contended_slots") => {
                        capacity_seen = true;
                        contended_set = true;
                        capacity.contended_slots = uint()? as u32;
                    }
                    ("capacity", "queue_capacity") => {
                        capacity_seen = true;
                        capacity.queue_capacity = uint()?;
                    }
                    ("capacity", "max_job_slots") => {
                        capacity_seen = true;
                        capacity.max_job_slots = uint()? as u32;
                    }
                    ("capacity", "queue_policy") => {
                        capacity_seen = true;
                        queue_policy = string()?;
                    }
                    ("capacity", "migration_limit") => {
                        capacity_seen = true;
                        capacity.migration_limit = uint()? as u32;
                    }
                    ("capacity", "priority_levels") => {
                        capacity_seen = true;
                        // Bound before narrowing: `as u8` would wrap 257
                        // into the valid range and silently disable
                        // priorities instead of rejecting the config.
                        capacity.priority_levels =
                            u8::try_from(uint()?).map_err(|_| {
                                anyhow::anyhow!("capacity.priority_levels out of range")
                            })?;
                    }
                    ("capacity", "slo_steps") => {
                        capacity_seen = true;
                        capacity.slo_steps = Some(uint()? as u32);
                    }
                    ("capacity", "host_class_slots") => {
                        capacity_seen = true;
                        host_class_slots = Some(num_array()?);
                    }
                    ("capacity", "host_class_weights") => {
                        capacity_seen = true;
                        host_class_weights = Some(num_array()?);
                    }

                    ("churn", "leave_hazard") => {
                        churn_seen = true;
                        churn.leave_hazard = num()?;
                    }
                    ("churn", "rejoin_delay_mean") => {
                        churn_seen = true;
                        churn.rejoin_delay_mean = num()?;
                    }
                    ("churn", "min_alive") => {
                        churn_seen = true;
                        churn.min_alive = uint()?;
                    }

                    ("failures", "rack_size") => {
                        failures_seen = true;
                        failures.rack_size = uint()?;
                    }
                    ("failures", "rack_outage_hazard") => {
                        failures_seen = true;
                        failures.rack_outage_hazard = num()?;
                    }
                    ("failures", "rack_outage_duration_mean") => {
                        failures_seen = true;
                        failures.rack_outage_duration_mean = num()?;
                    }
                    ("failures", "min_alive") => {
                        failures_seen = true;
                        failures.min_alive = uint()?;
                    }
                    ("failures", "partition_hazard") => {
                        failures_seen = true;
                        failures.partition_hazard = num()?;
                    }
                    ("failures", "partition_duration_mean") => {
                        failures_seen = true;
                        failures.partition_duration_mean = num()?;
                    }
                    ("failures", "partition_fraction") => {
                        failures_seen = true;
                        failures.partition_fraction = num()?;
                    }
                    ("failures", "partition_queue") => {
                        failures_seen = true;
                        failures.partition_queue = boolean()?;
                    }
                    ("failures", "straggler_fraction") => {
                        failures_seen = true;
                        failures.straggler_fraction = num()?;
                    }
                    ("failures", "straggler_delay_multiplier") => {
                        failures_seen = true;
                        failures.straggler_delay_multiplier = num()?;
                    }
                    ("failures", "straggler_observe_lag") => {
                        failures_seen = true;
                        failures.straggler_observe_lag = uint()?;
                    }
                    ("failures", "antagonist_rate") => {
                        failures_seen = true;
                        failures.antagonist_rate = num()?;
                    }
                    ("failures", "antagonist_priority") => {
                        failures_seen = true;
                        failures.antagonist_priority =
                            u8::try_from(uint()?).map_err(|_| {
                                anyhow::anyhow!("failures.antagonist_priority out of range")
                            })?;
                    }

                    ("federation", "enabled") => s.federation.enabled = boolean()?,
                    ("federation", "fanout") => s.federation.fanout = uint()?,
                    ("federation", "rank") => s.federation.rank = uint()?,
                    ("federation", "epsilon") => s.federation.epsilon = num()?,
                    ("federation", "push_every") => s.federation.push_every = uint()?,
                    ("federation", "latency") => latency_kind = Some(string()?),
                    ("federation", "latency_mean_steps") => latency_mean = Some(num()?),
                    ("federation", "latency_lo") => latency_lo = Some(num()?),
                    ("federation", "latency_hi") => latency_hi = Some(num()?),
                    ("federation", "pull_on_join") => s.federation.pull_on_join = boolean()?,
                    ("federation", "pull_forget") => s.federation.pull_forget = num()?,

                    _ => bail!("unknown scenario key [{section}] {key}"),
                }
            }
        }

        s.arrivals = match pattern.as_str() {
            "poisson" => ArrivalPattern::Poisson { rate },
            "bursty" => ArrivalPattern::Bursty {
                base_rate: rate,
                burst_rate,
                mean_burst_len,
                mean_gap_len,
            },
            "diurnal" => ArrivalPattern::Diurnal { base_rate: rate, amplitude, period_steps },
            "replay" => {
                let path = replay_path.as_deref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "arrivals.replay (a csv path) is required for pattern = \"replay\""
                    )
                })?;
                ArrivalPattern::Replay {
                    schedule: Arc::new(ReplaySchedule::from_path(
                        Path::new(path),
                        replay_metric.as_deref(),
                    )?),
                }
            }
            other => bail!("arrivals.pattern '{other}' (poisson | bursty | diurnal | replay)"),
        };
        if (replay_path.is_some() || replay_metric.is_some()) && pattern != "replay" {
            bail!("arrivals.replay/replay_metric require pattern = \"replay\"");
        }
        if capacity_seen {
            capacity.queue_policy = match queue_policy.as_str() {
                "fifo" => QueuePolicy::Fifo,
                "smallest-first" => QueuePolicy::SmallestFirst,
                other => bail!("capacity.queue_policy '{other}' (fifo | smallest-first)"),
            };
            if !contended_set {
                capacity.contended_slots = capacity.slots_per_node;
            }
            match (host_class_slots, host_class_weights) {
                (None, None) => {}
                (None, Some(_)) => {
                    bail!("capacity.host_class_weights requires host_class_slots")
                }
                (Some(slots), weights) => {
                    let weights = match weights {
                        Some(w) => {
                            if w.len() != slots.len() {
                                bail!(
                                    "capacity.host_class_weights ({}) and \
                                     host_class_slots ({}) must have the same length",
                                    w.len(),
                                    slots.len()
                                );
                            }
                            w
                        }
                        None => vec![1.0; slots.len()],
                    };
                    capacity.host_classes = slots
                        .iter()
                        .zip(&weights)
                        .map(|(&s, &w)| {
                            if s < 0.0 || s.fract() != 0.0 || s > u32::MAX as f64 {
                                bail!(
                                    "capacity.host_class_slots entries must be \
                                     non-negative integers (got {s})"
                                );
                            }
                            Ok(HostClass { slots: s as u32, weight: w })
                        })
                        .collect::<Result<_>>()?;
                }
            }
            s.capacity = Some(capacity);
        }
        // `dispatch` historically selected the candidate set; those values
        // still route to the probe policy so old scenario files keep
        // working. The scoring policies are the new first-class values.
        match dispatch.as_str() {
            "signal-only" => s.dispatch = DispatchPolicy::SignalOnly,
            "queue-aware" => s.dispatch = DispatchPolicy::QueueAware,
            "least-loaded" => s.dispatch = DispatchPolicy::LeastLoaded,
            "random" | "round-robin" | "power-of-k" => {
                if probe_set {
                    bail!(
                        "scenario.dispatch '{dispatch}' is a legacy probe value and \
                         conflicts with the explicit scenario.probe '{probe}'"
                    );
                }
                probe = dispatch.clone();
                s.dispatch = DispatchPolicy::SignalOnly;
            }
            other => bail!(
                "scenario.dispatch '{other}' (signal-only | queue-aware | least-loaded; \
                 legacy probe values random | round-robin | power-of-k also accepted)"
            ),
        }
        s.probe = match probe.as_str() {
            "random" => ProbePolicy::RandomProbe,
            "round-robin" => ProbePolicy::RoundRobin,
            "power-of-k" => ProbePolicy::PowerOfK(probe_k.max(1)),
            other => bail!("scenario.probe '{other}' (random | round-robin | power-of-k)"),
        };
        // Selector + parameters must agree; a parameter on its own infers
        // its model (matching the main config's behaviour) rather than
        // silently running the zero-latency baseline.
        let mean = || -> Result<f64> {
            latency_mean
                .ok_or_else(|| anyhow::anyhow!("federation.latency_mean_steps required"))
        };
        s.federation.latency = match latency_kind.as_deref() {
            Some("none") => LatencyModel::None,
            Some("constant") => LatencyModel::Constant { steps: mean()? },
            Some("exponential") => LatencyModel::Exponential { mean_steps: mean()? },
            Some("uniform") => LatencyModel::Uniform {
                lo: latency_lo
                    .ok_or_else(|| anyhow::anyhow!("federation.latency_lo required"))?,
                hi: latency_hi
                    .ok_or_else(|| anyhow::anyhow!("federation.latency_hi required"))?,
            },
            Some(other) => bail!(
                "federation.latency '{other}' (none | constant | exponential | uniform)"
            ),
            None => match (latency_mean, latency_lo, latency_hi) {
                (None, None, None) => LatencyModel::None,
                (Some(m), None, None) => LatencyModel::Exponential { mean_steps: m },
                (None, Some(lo), Some(hi)) => LatencyModel::Uniform { lo, hi },
                _ => bail!(
                    "federation latency parameters are ambiguous without a \
                     `latency = \"...\"` selector"
                ),
            },
        };
        if churn_seen {
            s.churn = Some(churn);
        }
        if failures_seen {
            s.failures = Some(failures);
        }
        s.validate()?;
        Ok(s)
    }

    /// Sanity-check the composition.
    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.steps == 0 {
            bail!("scenario: nodes and steps must be positive");
        }
        if self.threads == 0 || self.threads > 256 {
            bail!(
                "scenario: threads ({}) must be in [1, 256] (1 = sequential)",
                self.threads
            );
        }
        if self.federation.fanout < 2 {
            bail!("scenario: federation.fanout must be >= 2");
        }
        if self.federation.push_every == 0 {
            bail!("scenario: federation.push_every must be >= 1");
        }
        if let Some(c) = &self.churn {
            if !(0.0..=1.0).contains(&c.leave_hazard) {
                bail!("scenario: churn.leave_hazard must be in [0, 1]");
            }
            if c.min_alive >= self.nodes {
                bail!(
                    "scenario: churn.min_alive ({}) must be below nodes ({}) \
                     or churn can never fire",
                    c.min_alive,
                    self.nodes
                );
            }
        }
        if let Some(c) = &self.capacity {
            if c.slots_per_node == 0 {
                bail!("scenario: capacity.slots_per_node must be >= 1");
            }
            for hc in &c.host_classes {
                if hc.slots == 0 {
                    bail!("scenario: capacity host class slots must be >= 1");
                }
                if !(hc.weight.is_finite() && hc.weight > 0.0) {
                    bail!("scenario: capacity host class weights must be positive");
                }
            }
            // Demand is clamped to the placed host's budget at hand-off,
            // so only the *largest* host class must fit the biggest draw —
            // otherwise some jobs could never start anywhere.
            if c.max_job_slots == 0 || c.max_job_slots > c.max_host_slots() {
                bail!(
                    "scenario: capacity.max_job_slots ({}) must be in \
                     [1, largest host budget = {}] or some jobs can never start",
                    c.max_job_slots,
                    c.max_host_slots()
                );
            }
            if c.contended_slots > c.slots_per_node {
                bail!(
                    "scenario: capacity.contended_slots ({}) must not exceed \
                     slots_per_node ({})",
                    c.contended_slots,
                    c.slots_per_node
                );
            }
            if c.priority_levels == 0 || c.priority_levels > 8 {
                bail!(
                    "scenario: capacity.priority_levels ({}) must be in [1, 8]",
                    c.priority_levels
                );
            }
            if c.slo_steps == Some(0) {
                bail!("scenario: capacity.slo_steps must be >= 1");
            }
        }
        if let Some(f) = &self.failures {
            let frac01 = |x: f64| x.is_finite() && (0.0..=1.0).contains(&x);
            if !frac01(f.rack_outage_hazard) || !frac01(f.partition_hazard) {
                bail!("scenario: failure hazards must be probabilities in [0, 1]");
            }
            if !frac01(f.straggler_fraction) {
                bail!("scenario: failures.straggler_fraction must be in [0, 1]");
            }
            if f.rack_outages_enabled() {
                if f.rack_size == 0 {
                    bail!("scenario: failures.rack_size must be >= 1");
                }
                if !(f.rack_outage_duration_mean > 0.0) {
                    bail!("scenario: failures.rack_outage_duration_mean must be positive");
                }
                if f.min_alive >= self.nodes {
                    bail!(
                        "scenario: failures.min_alive ({}) must be below nodes ({}) \
                         or no rack can ever fail",
                        f.min_alive,
                        self.nodes
                    );
                }
            }
            if f.partitions_enabled() {
                if !self.federation.enabled {
                    bail!(
                        "scenario: failures.partition_hazard needs federation.enabled \
                         (there is no tree to partition)"
                    );
                }
                if !(f.partition_duration_mean > 0.0) {
                    bail!("scenario: failures.partition_duration_mean must be positive");
                }
                if !(f.partition_fraction > 0.0 && f.partition_fraction <= 1.0) {
                    bail!("scenario: failures.partition_fraction must be in (0, 1]");
                }
            }
            if f.stragglers_enabled() && !(f.straggler_delay_multiplier >= 1.0) {
                bail!("scenario: failures.straggler_delay_multiplier must be >= 1");
            }
            if !(f.antagonist_rate.is_finite() && f.antagonist_rate >= 0.0) {
                bail!("scenario: failures.antagonist_rate must be finite and non-negative");
            }
        }
        // Each regime's rate must be valid on its own — a healthy mean
        // can hide a negative burst rate that would panic the Poisson
        // sampler (debug) or silently zero arrivals (release).
        let rate_ok = |r: f64| r.is_finite() && r >= 0.0;
        match &self.arrivals {
            ArrivalPattern::Poisson { rate } => {
                if !rate_ok(*rate) {
                    bail!("scenario: arrivals.rate must be finite and non-negative");
                }
            }
            ArrivalPattern::Bursty {
                base_rate,
                burst_rate,
                mean_burst_len,
                mean_gap_len,
            } => {
                if !rate_ok(*base_rate) || !rate_ok(*burst_rate) {
                    bail!("scenario: bursty rates must be finite and non-negative");
                }
                if !(*mean_burst_len > 0.0 && *mean_gap_len > 0.0) {
                    bail!("scenario: bursty regime lengths must be positive");
                }
            }
            ArrivalPattern::Diurnal { base_rate, amplitude, period_steps } => {
                if !rate_ok(*base_rate) || !amplitude.is_finite() {
                    bail!("scenario: diurnal rate/amplitude must be finite (rate >= 0)");
                }
                if *period_steps == 0 {
                    bail!("scenario: diurnal period_steps must be >= 1");
                }
            }
            ArrivalPattern::Replay { schedule } => {
                if schedule.is_empty() {
                    bail!("scenario: replay schedule has no steps");
                }
            }
        }
        Ok(())
    }

    /// Builder-style overrides used by the CLI and benches.
    pub fn with_nodes(mut self, nodes: usize) -> Self {
        self.nodes = nodes;
        self
    }

    pub fn with_steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_names_all_resolve() {
        for name in CATALOG {
            let s = Scenario::named(name).expect(name);
            s.validate().expect(name);
            assert_eq!(&s.name, name);
        }
        assert!(Scenario::named("nope").is_none());
    }

    #[test]
    fn bursty_rates_follow_regime() {
        let a = ArrivalPattern::Bursty {
            base_rate: 0.1,
            burst_rate: 2.0,
            mean_burst_len: 10.0,
            mean_gap_len: 90.0,
        };
        assert_eq!(a.rate_at(5, false), 0.1);
        assert_eq!(a.rate_at(5, true), 2.0);
        assert!((a.mean_rate() - (2.0 * 10.0 + 0.1 * 90.0) / 100.0).abs() < 1e-12);
    }

    #[test]
    fn diurnal_rate_oscillates_and_stays_nonnegative() {
        let a = ArrivalPattern::Diurnal { base_rate: 0.2, amplitude: 1.5, period_steps: 100 };
        let rates: Vec<f64> = (0..100).map(|t| a.rate_at(t, false)).collect();
        assert!(rates.iter().all(|&r| r >= 0.0));
        let max = rates.iter().cloned().fold(0.0, f64::max);
        let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max > 0.4 && min == 0.0, "max={max} min={min}");
    }

    #[test]
    fn toml_scenario_parses_fully() {
        let s = Scenario::from_toml(
            r#"
[scenario]
name = "wan-storm"
nodes = 24
steps = 1500
seed = 99
dispatch = "power-of-k"
probe_k = 3
score_window = 8

[arrivals]
pattern = "bursty"
rate = 0.2
burst_rate = 2.5
mean_burst_len = 30
mean_gap_len = 150

[churn]
leave_hazard = 0.002
rejoin_delay_mean = 60
min_alive = 6

[federation]
enabled = true
push_every = 32
latency = "exponential"
latency_mean_steps = 5.0
"#,
        )
        .unwrap();
        assert_eq!(s.name, "wan-storm");
        assert_eq!(s.nodes, 24);
        // Legacy `dispatch = "power-of-k"` routes to the probe policy.
        assert_eq!(s.probe, ProbePolicy::PowerOfK(3));
        assert_eq!(s.dispatch, DispatchPolicy::SignalOnly);
        assert!(matches!(s.arrivals, ArrivalPattern::Bursty { burst_rate, .. } if burst_rate == 2.5));
        let churn = s.churn.unwrap();
        assert_eq!(churn.min_alive, 6);
        assert!(s.federation.enabled);
        assert_eq!(s.federation.push_every, 32);
        assert_eq!(
            s.federation.latency,
            LatencyModel::Exponential { mean_steps: 5.0 }
        );
    }

    #[test]
    fn toml_rejects_unknown_keys_and_bad_enums() {
        assert!(Scenario::from_toml("[scenario]\nnodez = 2\n").is_err());
        assert!(Scenario::from_toml("[arrivals]\npattern = \"fractal\"\n").is_err());
        assert!(Scenario::from_toml("[federation]\nlatency = \"psychic\"\n").is_err());
        assert!(Scenario::from_toml("[scenario]\nnodes = 0\n").is_err());
        assert!(Scenario::from_toml("[scenario]\ndispatch = \"psychic\"\n").is_err());
        assert!(Scenario::from_toml("[scenario]\nprobe = \"signal-only\"\n").is_err());
    }

    #[test]
    fn threads_knob_parses_and_validates() {
        // Unset keeps the sequential default.
        let s = Scenario::from_toml("[scenario]\nnodes = 4\n").unwrap();
        assert_eq!(s.threads, 1);
        let s = Scenario::from_toml("[scenario]\nthreads = 4\n").unwrap();
        assert_eq!(s.threads, 4);
        // 0 and absurd widths are rejected, not clamped silently.
        assert!(Scenario::from_toml("[scenario]\nthreads = 0\n").is_err());
        assert!(Scenario::from_toml("[scenario]\nthreads = 1000\n").is_err());
        assert!(Scenario::default().with_threads(0).validate().is_err());
        assert!(Scenario::default().with_threads(7).validate().is_ok());
    }

    #[test]
    fn dispatch_and_probe_parse_independently() {
        let s = Scenario::from_toml(
            "[scenario]\ndispatch = \"queue-aware\"\nprobe = \"round-robin\"\n",
        )
        .unwrap();
        assert_eq!(s.dispatch, DispatchPolicy::QueueAware);
        assert_eq!(s.probe, ProbePolicy::RoundRobin);
        let s = Scenario::from_toml("[scenario]\ndispatch = \"least-loaded\"\n").unwrap();
        assert_eq!(s.dispatch, DispatchPolicy::LeastLoaded);
        assert_eq!(s.probe, ProbePolicy::PowerOfK(2), "default probe");
        // Defaults preserve the paper's behaviour.
        let s = Scenario::from_toml("[scenario]\nnodes = 4\n").unwrap();
        assert_eq!(s.dispatch, DispatchPolicy::SignalOnly);
        // A legacy dispatch value may not silently clobber an explicit
        // probe key — that contradiction is an error.
        assert!(Scenario::from_toml(
            "[scenario]\nprobe = \"round-robin\"\ndispatch = \"power-of-k\"\n"
        )
        .is_err());
        // Legacy routing alone still works.
        let s = Scenario::from_toml("[scenario]\ndispatch = \"random\"\n").unwrap();
        assert_eq!(s.probe, ProbePolicy::RandomProbe);
        assert_eq!(s.dispatch, DispatchPolicy::SignalOnly);
    }

    #[test]
    fn priorities_slo_and_host_classes_parse_and_validate() {
        let s = Scenario::from_toml(
            r#"
[capacity]
slots_per_node = 2
max_job_slots = 2
priority_levels = 3
slo_steps = 25
host_class_slots = [1, 2, 4]
host_class_weights = [0.25, 0.5, 0.25]
"#,
        )
        .unwrap();
        let c = s.capacity.unwrap();
        assert_eq!(c.priority_levels, 3);
        assert_eq!(c.slo_steps, Some(25));
        assert_eq!(c.host_classes.len(), 3);
        assert_eq!(c.host_classes[2], HostClass { slots: 4, weight: 0.25 });
        assert_eq!(c.max_host_slots(), 4);

        // Weights default equal when only slots are given.
        let s = Scenario::from_toml("[capacity]\nhost_class_slots = [2, 6]\n").unwrap();
        let c = s.capacity.unwrap();
        assert_eq!(c.host_classes.len(), 2);
        assert_eq!(c.host_classes[0].weight, c.host_classes[1].weight);

        // Invalid compositions fail loudly.
        assert!(
            Scenario::from_toml("[capacity]\nhost_class_weights = [1.0]\n").is_err(),
            "weights without slots"
        );
        assert!(Scenario::from_toml(
            "[capacity]\nhost_class_slots = [1, 2]\nhost_class_weights = [1.0]\n"
        )
        .is_err());
        assert!(Scenario::from_toml("[capacity]\nhost_class_slots = [0, 2]\n").is_err());
        assert!(
            Scenario::from_toml("[capacity]\nhost_class_slots = [1.5, 2]\n").is_err(),
            "fractional budgets must not truncate silently"
        );
        assert!(Scenario::from_toml("[capacity]\npriority_levels = 0\n").is_err());
        assert!(Scenario::from_toml("[capacity]\npriority_levels = 9\n").is_err());
        assert!(
            Scenario::from_toml("[capacity]\npriority_levels = 257\n").is_err(),
            "u8 wrap-around must not sneak back into range"
        );
        assert!(Scenario::from_toml("[capacity]\nslo_steps = 0\n").is_err());
        // max_job_slots is checked against the *largest* class.
        assert!(Scenario::from_toml(
            "[capacity]\nhost_class_slots = [1, 2]\nmax_job_slots = 4\n"
        )
        .is_err());
        assert!(Scenario::from_toml(
            "[capacity]\nslots_per_node = 1\nhost_class_slots = [1, 4]\nmax_job_slots = 3\n"
        )
        .is_ok());
    }

    #[test]
    fn host_class_draws_are_deterministic_and_weighted() {
        let c = CapacityModel {
            host_classes: vec![
                HostClass { slots: 1, weight: 0.25 },
                HostClass { slots: 2, weight: 0.5 },
                HostClass { slots: 4, weight: 0.25 },
            ],
            ..CapacityModel::default()
        };
        let draw_fleet = |seed: u64| -> Vec<u32> {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            (0..200).map(|_| c.draw_slots(&mut rng)).collect()
        };
        let a = draw_fleet(9);
        assert_eq!(a, draw_fleet(9), "class assignment not deterministic");
        for slots in [1u32, 2, 4] {
            assert!(a.contains(&slots), "class {slots} never drawn");
        }
        let twos = a.iter().filter(|&&s| s == 2).count();
        assert!((60..=140).contains(&twos), "weights ignored: {twos}/200 twos");
        // Homogeneous model consumes no randomness and returns the base.
        let mut rng = Xoshiro256::seed_from_u64(1);
        let before = rng.clone().next_u64();
        assert_eq!(CapacityModel::default().draw_slots(&mut rng), 4);
        assert_eq!(rng.next_u64(), before, "homogeneous draw consumed RNG");
    }

    #[test]
    fn latency_params_infer_model_and_selector_requires_params() {
        // A bare mean selects the exponential model (as in the main
        // config) instead of silently running with instant delivery.
        let s =
            Scenario::from_toml("[federation]\nlatency_mean_steps = 6.0\n").unwrap();
        assert_eq!(s.federation.latency, LatencyModel::Exponential { mean_steps: 6.0 });
        let s = Scenario::from_toml("[federation]\nlatency_lo = 1\nlatency_hi = 3\n")
            .unwrap();
        assert_eq!(s.federation.latency, LatencyModel::Uniform { lo: 1.0, hi: 3.0 });
        // Selector without its parameter is an error, not instant.
        assert!(Scenario::from_toml("[federation]\nlatency = \"exponential\"\n").is_err());
        assert!(Scenario::from_toml("[federation]\nlatency = \"uniform\"\n").is_err());
        // Mixed parameters without a selector are ambiguous.
        assert!(Scenario::from_toml(
            "[federation]\nlatency_mean_steps = 2\nlatency_lo = 1\nlatency_hi = 3\n"
        )
        .is_err());
    }

    #[test]
    fn per_regime_arrival_rates_are_validated() {
        assert!(Scenario::from_toml(
            "[arrivals]\npattern = \"bursty\"\nrate = 1.0\nburst_rate = -0.5\n"
        )
        .is_err());
        assert!(Scenario::from_toml("[arrivals]\nrate = -0.1\n").is_err());
        assert!(Scenario::from_toml(
            "[arrivals]\npattern = \"diurnal\"\nperiod_steps = 0\n"
        )
        .is_err());
        assert!(Scenario::from_toml(
            "[arrivals]\npattern = \"bursty\"\nmean_burst_len = 0\n"
        )
        .is_err());
    }

    #[test]
    fn churn_floor_must_leave_room_to_churn() {
        assert!(Scenario::from_toml(
            "[scenario]\nnodes = 4\n[churn]\nmin_alive = 4\n"
        )
        .is_err());
        assert!(Scenario::from_toml(
            "[scenario]\nnodes = 5\n[churn]\nmin_alive = 4\n"
        )
        .is_ok());
    }

    #[test]
    fn capacity_toml_section_enables_and_validates() {
        let s = Scenario::from_toml(
            r#"
[capacity]
slots_per_node = 8
queue_capacity = 16
max_job_slots = 2
queue_policy = "smallest-first"
migration_limit = 3
"#,
        )
        .unwrap();
        let c = s.capacity.unwrap();
        assert_eq!(c.slots_per_node, 8);
        // Unset contended budget defaults to the full budget.
        assert_eq!(c.contended_slots, 8);
        assert_eq!(c.queue_policy, QueuePolicy::SmallestFirst);
        assert_eq!(c.migration_limit, 3);

        let s = Scenario::from_toml("[capacity]\nslots_per_node = 4\ncontended_slots = 1\n")
            .unwrap();
        assert_eq!(s.capacity.unwrap().contended_slots, 1);

        // Invalid compositions fail loudly.
        assert!(Scenario::from_toml("[capacity]\nslots_per_node = 0\n").is_err());
        assert!(
            Scenario::from_toml("[capacity]\nslots_per_node = 2\nmax_job_slots = 3\n").is_err()
        );
        assert!(Scenario::from_toml(
            "[capacity]\nslots_per_node = 2\ncontended_slots = 5\n"
        )
        .is_err());
        assert!(Scenario::from_toml("[capacity]\nqueue_policy = \"lifo\"\n").is_err());
    }

    #[test]
    fn replay_pattern_requires_and_loads_csv() {
        // Missing path is an error, not a silent empty schedule.
        assert!(Scenario::from_toml("[arrivals]\npattern = \"replay\"\n").is_err());
        // A replay path or metric with a non-replay pattern is a likely typo.
        assert!(Scenario::from_toml("[arrivals]\nreplay = \"x.csv\"\n").is_err());
        assert!(Scenario::from_toml("[arrivals]\nreplay_metric = \"jobs\"\n").is_err());

        let dir = std::env::temp_dir().join("pronto_scenario_replay_toml");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("arrivals.csv");
        std::fs::write(&p, "timestep,arrivals\n0,2\n1,0\n2,1\n").unwrap();
        let text = format!(
            "[arrivals]\npattern = \"replay\"\nreplay = \"{}\"\n",
            p.display()
        );
        let s = Scenario::from_toml(&text).unwrap();
        match &s.arrivals {
            ArrivalPattern::Replay { schedule } => {
                assert_eq!(schedule.len(), 3);
                assert_eq!(schedule.total(), 3);
                assert_eq!(schedule.count_at(0), 2);
                assert_eq!(schedule.count_at(2), 1);
                assert_eq!(schedule.count_at(99), 0);
            }
            other => panic!("expected replay pattern, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn replay_directory_merges_per_vm_sequences() {
        let dir = std::env::temp_dir().join("pronto_scenario_replay_dir");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("vm0.csv"), "timestep,arrivals\n0,1\n1,0\n2,2\n").unwrap();
        std::fs::write(dir.join("vm1.csv"), "timestep,arrivals\n0,0\n1,3\n").unwrap();
        let sched = ReplaySchedule::from_path(&dir, None).unwrap();
        // Per-step sums over both VMs, padded to the longest sequence.
        assert_eq!(sched.len(), 3);
        assert_eq!(sched.count_at(0), 1);
        assert_eq!(sched.count_at(1), 3);
        assert_eq!(sched.count_at(2), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn demo_replay_schedule_is_deterministic() {
        let a = ReplaySchedule::demo(500);
        let b = ReplaySchedule::demo(500);
        assert_eq!(a, b);
        assert!(a.total() > 0);
        assert_eq!(a.count_at(0), 3);
        let mean = a.total() as f64 / a.len() as f64;
        assert!(mean > 0.1 && mean < 0.4, "demo rate {mean} out of family");
    }

    #[test]
    fn new_catalog_entries_compose_as_documented() {
        let cap = Scenario::named("capacity").unwrap();
        let c = cap.capacity.unwrap();
        assert_eq!(c.migration_limit, 0);
        assert!(cap.churn.is_none());
        // Offered load exceeds the fleet's slot budget — the point.
        let offered = cap.arrivals.mean_rate()
            * (cap.duration_mu + 0.5 * cap.duration_sigma * cap.duration_sigma).exp();
        assert!(offered > (cap.nodes as u32 * c.slots_per_node) as f64);

        let pre = Scenario::named("preemption").unwrap();
        let c = pre.capacity.unwrap();
        assert!(c.contended_slots < c.slots_per_node, "pressure preemption off");
        assert!(c.migration_limit > 0);
        assert!(pre.churn.is_some());

        let rep = Scenario::named("replay").unwrap();
        assert!(matches!(rep.arrivals, ArrivalPattern::Replay { .. }));

        // `queue-aware` is the `capacity` overload with scored placement.
        let qa = Scenario::named("queue-aware").unwrap();
        assert_eq!(qa.dispatch, DispatchPolicy::QueueAware);
        assert_eq!(qa.capacity, cap_model_of("capacity"));

        let pri = Scenario::named("priority").unwrap();
        let c = pri.capacity.unwrap();
        assert_eq!(c.priority_levels, 3);
        assert_eq!(c.slo_steps, Some(30));

        let het = Scenario::named("hetero").unwrap();
        assert_eq!(het.dispatch, DispatchPolicy::LeastLoaded);
        let c = het.capacity.unwrap();
        assert_eq!(c.host_classes.len(), 3);
        // max_job_slots exceeds the smallest class: the clamp path is
        // exercised by design, and the largest class covers the draw.
        assert!(c.max_job_slots > c.host_classes[0].slots);
        assert!(c.max_job_slots <= c.max_host_slots());

        // The scale entries: steady load inside the fleet budget for
        // `large-fleet`, storms far beyond it for `flash-crowd`.
        let mean_duration = |s: &Scenario| {
            (s.duration_mu + 0.5 * s.duration_sigma * s.duration_sigma).exp()
        };
        let lf = Scenario::named("large-fleet").unwrap();
        assert_eq!(lf.nodes, 2_000);
        let c = lf.capacity.as_ref().unwrap();
        let budget = (lf.nodes as u32 * c.slots_per_node) as f64;
        let offered = lf.arrivals.mean_rate() * mean_duration(&lf);
        assert!(
            offered > 0.4 * budget && offered < budget,
            "large-fleet load {offered:.0} out of family for budget {budget:.0}"
        );

        let fc = Scenario::named("flash-crowd").unwrap();
        assert_eq!(fc.nodes, 1_000);
        assert_eq!(fc.dispatch, DispatchPolicy::QueueAware);
        let c = fc.capacity.as_ref().unwrap();
        let budget = (fc.nodes as u32 * c.slots_per_node) as f64;
        match &fc.arrivals {
            ArrivalPattern::Bursty { base_rate, burst_rate, .. } => {
                assert!(base_rate * mean_duration(&fc) < 0.5 * budget, "baseline too hot");
                assert!(
                    burst_rate * mean_duration(&fc) > 2.0 * budget,
                    "storms must flood the fleet"
                );
            }
            other => panic!("flash-crowd must be bursty, got {other:?}"),
        }
    }

    fn cap_model_of(name: &str) -> Option<CapacityModel> {
        Scenario::named(name).unwrap().capacity
    }

    #[test]
    fn failure_catalog_entries_compose_as_documented() {
        let ro = Scenario::named("rack-outage").unwrap();
        let f = ro.failures.unwrap();
        assert!(f.rack_outages_enabled());
        assert!(f.induces_churn());
        assert!(ro.has_node_churn(), "rack outages must gate the rejoin factory");
        assert!(ro.churn.is_none(), "outages come from the failure layer alone");
        assert_eq!(ro.nodes % f.rack_size, 0, "partial racks complicate the sweep");
        assert!(f.min_alive < ro.nodes);
        assert!(ro.capacity.as_ref().unwrap().migration_limit > 0);

        let pa = Scenario::named("partition").unwrap();
        let f = pa.failures.unwrap();
        assert!(f.partitions_enabled() && f.partition_queue);
        assert!(pa.federation.enabled, "nothing to partition without a tree");
        assert!(!pa.has_node_churn(), "partitions cut uplinks, not nodes");

        let st = Scenario::named("straggler").unwrap();
        let f = st.failures.unwrap();
        assert!(f.stragglers_enabled());
        assert!(f.straggler_delay_multiplier > 1.0);
        assert!(f.straggler_observe_lag > 0);
        assert!(
            !st.federation.latency.is_instant(),
            "a delay multiplier on instant pushes would be inert"
        );

        let an = Scenario::named("antagonist").unwrap();
        let f = an.failures.unwrap();
        assert!(f.antagonist_enabled());
        let c = an.capacity.unwrap();
        assert!(f.antagonist_priority < c.priority_levels);
        assert!(c.slo_steps.is_some(), "per-tenant attainment needs an SLO");
    }

    #[test]
    fn failures_toml_section_enables_and_validates() {
        let s = Scenario::from_toml(
            r#"
[federation]
enabled = true

[failures]
rack_size = 4
rack_outage_hazard = 0.003
rack_outage_duration_mean = 50
min_alive = 6
partition_hazard = 0.002
partition_fraction = 0.5
partition_queue = false
straggler_fraction = 0.25
straggler_delay_multiplier = 6
straggler_observe_lag = 4
antagonist_rate = 0.4
antagonist_priority = 1
"#,
        )
        .unwrap();
        let f = s.failures.unwrap();
        assert_eq!(f.rack_size, 4);
        assert_eq!(f.rack_outage_hazard, 0.003);
        assert_eq!(f.min_alive, 6);
        assert!(!f.partition_queue);
        assert_eq!(f.straggler_observe_lag, 4);
        assert_eq!(f.antagonist_priority, 1);
        assert!(s.has_node_churn());

        // Unknown keys and invalid compositions fail loudly.
        assert!(Scenario::from_toml("[failures]\nrack_hazard = 0.1\n").is_err());
        assert!(Scenario::from_toml("[failures]\nrack_outage_hazard = 1.5\n").is_err());
        assert!(Scenario::from_toml(
            "[failures]\nrack_outage_hazard = 0.01\nrack_size = 0\n"
        )
        .is_err());
        assert!(
            Scenario::from_toml("[failures]\npartition_hazard = 0.01\n").is_err(),
            "partitions without federation must be rejected"
        );
        assert!(Scenario::from_toml(
            "[federation]\nenabled = true\n[failures]\npartition_hazard = 0.01\n\
             partition_fraction = 0\n"
        )
        .is_err());
        assert!(Scenario::from_toml(
            "[failures]\nstraggler_fraction = 0.2\nstraggler_delay_multiplier = 0.5\n"
        )
        .is_err());
        assert!(Scenario::from_toml("[failures]\nantagonist_rate = -1\n").is_err());
        assert!(Scenario::from_toml(
            "[scenario]\nnodes = 8\n[failures]\nrack_outage_hazard = 0.01\nmin_alive = 8\n"
        )
        .is_err());

        // An inert section parses (all hazards at their zero defaults).
        let s = Scenario::from_toml("[failures]\nrack_size = 8\n").unwrap();
        let f = s.failures.unwrap();
        assert!(!f.rack_outages_enabled() && !f.antagonist_enabled());
        assert!(!s.has_node_churn());
    }

    #[test]
    fn resolve_prefers_catalog_then_path() {
        assert!(Scenario::resolve("bursty").is_ok());
        assert!(Scenario::resolve("no-such-scenario").is_err());
        let dir = std::env::temp_dir().join("pronto_scenario_resolve");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.toml");
        std::fs::write(&p, "[scenario]\nname = \"from-file\"\nnodes = 4\n").unwrap();
        let s = Scenario::resolve(&p.to_string_lossy()).unwrap();
        assert_eq!(s.name, "from-file");
        std::fs::remove_dir_all(&dir).ok();
    }
}
