"""L2 decompositions vs scipy/numpy ground truth."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.linalg import householder_qr, svd_topk

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(m=st.integers(1, 40), n=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_qr_reconstructs_and_is_orthonormal(m, n, seed):
    if m < n:
        m, n = n, m
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, n)).astype(np.float32)
    q, r = householder_qr(jnp.asarray(a))
    q, r = np.asarray(q), np.asarray(r)
    np.testing.assert_allclose(q @ r, a, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(q.T @ q, np.eye(n), atol=1e-4)
    # Upper triangular with non-negative diagonal (uniqueness convention).
    assert np.all(np.diag(r) >= -1e-6)
    assert np.allclose(r, np.triu(r), atol=1e-6)


def test_qr_matches_numpy_on_fixed_case():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((20, 5)).astype(np.float64)
    q, r = householder_qr(jnp.asarray(a))
    qn, rn = np.linalg.qr(a)
    # Fix numpy's sign convention to ours.
    sign = np.sign(np.diag(rn))
    sign[sign == 0] = 1.0
    qn, rn = qn * sign[None, :], rn * sign[:, None]
    np.testing.assert_allclose(np.asarray(q), qn, atol=1e-8)
    np.testing.assert_allclose(np.asarray(r), rn, atol=1e-8)


def test_qr_rank_deficient_is_finite():
    a = np.ones((6, 3), dtype=np.float32)
    q, r = householder_qr(jnp.asarray(a))
    assert np.all(np.isfinite(np.asarray(q)))
    np.testing.assert_allclose(np.asarray(q) @ np.asarray(r), a, atol=1e-5)


@given(
    d=st.integers(4, 64),
    c=st.integers(2, 36),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_svd_topk_matches_numpy(d, c, k, seed):
    if d < c:
        d, c = c, d
    k = min(k, c)
    rng = np.random.default_rng(seed)
    # Low-rank-plus-noise input: realistic spectrum with a gap, which is
    # what the FPCA update always feeds this routine.
    r_true = min(4, c)
    a = (
        rng.standard_normal((d, r_true)) @ rng.standard_normal((r_true, c))
        + 0.01 * rng.standard_normal((d, c))
    ).astype(np.float32)
    u, s, v = svd_topk(jnp.asarray(a), k, iters=40)
    u, s = np.asarray(u), np.asarray(s)
    sn = np.linalg.svd(a, compute_uv=False)[:k]
    np.testing.assert_allclose(s, sn, rtol=2e-2, atol=2e-3)
    # u columns orthonormal where sigma > 0.
    nz = s > 1e-5
    if nz.any():
        g = u[:, nz].T @ u[:, nz]
        np.testing.assert_allclose(g, np.eye(nz.sum()), atol=5e-3)


def test_svd_topk_reconstruction_error_is_tail_energy():
    rng = np.random.default_rng(3)
    d, c, k = 30, 10, 3
    a = rng.standard_normal((d, c)).astype(np.float64)
    u, s, v = svd_topk(jnp.asarray(a), k, iters=60)
    approx = np.asarray(u) @ np.diag(np.asarray(s)) @ np.asarray(v).T
    err = np.linalg.norm(a - approx)
    tail = np.sqrt((np.linalg.svd(a, compute_uv=False)[k:] ** 2).sum())
    assert err <= tail * 1.05 + 1e-8, f"err={err} tail={tail}"


def test_svd_topk_zero_matrix():
    a = jnp.zeros((10, 5), dtype=jnp.float32)
    u, s, v = svd_topk(a, 3)
    assert np.all(np.asarray(s) == 0.0)
    assert np.all(np.isfinite(np.asarray(u)))
