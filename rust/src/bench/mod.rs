//! Micro/macro-benchmark harness (criterion replacement).
//!
//! `cargo bench` targets in `rust/benches/` are `harness = false` binaries
//! built on this module: warmup, calibrated iteration counts, robust
//! statistics (median + p10/p90), and plain-text table output matching the
//! paper's rows so EXPERIMENTS.md can diff paper-vs-measured directly.

pub mod diff;
pub mod engine;
pub mod experiments;
pub mod sweep;

pub use diff::{bench_diff, parse_bench_rows, BenchDiff, RowDiff, RowKey};
pub use engine::{
    bench_engine, bench_engine_report, bench_engine_run, EngineBenchConfig, EngineBenchRun,
    ScaleRow, DEFAULT_BENCH_SCENARIOS,
};
pub use sweep::{run_sweep, run_sweep_cell, sweep_report, sweep_table, SweepConfig, SweepRow};

use std::time::{Duration, Instant};

/// One benchmark measurement summary (nanoseconds).
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
}

impl Sample {
    /// Human-friendly time with unit scaling.
    pub fn human(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.2} s", ns / 1e9)
        }
    }
}

/// Benchmark runner with warmup and a measurement budget.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_batches: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_batches: 20,
        }
    }
}

impl Bencher {
    /// Quick-mode bencher for CI (shorter budget).
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(400),
            min_batches: 10,
        }
    }

    /// Honour `PRONTO_BENCH_QUICK=1` (used by `make test` smoke runs).
    pub fn from_env() -> Self {
        if std::env::var("PRONTO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
            Self::quick()
        } else {
            Self::default()
        }
    }

    /// Measure `f`, returning robust statistics. `f` should perform one
    /// logical operation; the harness batches calls to amortize timer costs.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Sample {
        // Warmup + per-call cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        let per_call = warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64;

        // Pick a batch size so each batch is ~budget/min_batches.
        let batch_target_ns = self.budget.as_nanos() as f64 / self.min_batches as f64;
        let batch = ((batch_target_ns / per_call.max(1.0)).ceil() as u64).max(1);

        let mut batch_means: Vec<f64> = Vec::new();
        let start = Instant::now();
        let mut total_iters = 0u64;
        while start.elapsed() < self.budget || batch_means.len() < self.min_batches {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64;
            batch_means.push(dt / batch as f64);
            total_iters += batch;
            if batch_means.len() > 10_000 {
                break; // safety for ultra-fast ops
            }
        }

        batch_means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let idx = ((batch_means.len() - 1) as f64 * p).round() as usize;
            batch_means[idx]
        };
        let mean = batch_means.iter().sum::<f64>() / batch_means.len() as f64;
        Sample {
            name: name.to_string(),
            iters: total_iters,
            median_ns: q(0.5),
            p10_ns: q(0.1),
            p90_ns: q(0.9),
            mean_ns: mean,
        }
    }
}

/// Fixed-width text table used by every bench binary so table/figure output
/// is uniform and diffable against the paper.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with padded columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout (bench binaries' primary output path).
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Also emit machine-readable CSV next to the human table when
    /// `PRONTO_BENCH_CSV_DIR` is set (used to collect series for figures).
    pub fn maybe_write_csv(&self, stem: &str) {
        if let Ok(dir) = std::env::var("PRONTO_BENCH_CSV_DIR") {
            let _ = std::fs::create_dir_all(&dir);
            let path = format!("{dir}/{stem}.csv");
            let mut s = String::new();
            s.push_str(&self.header.join(","));
            s.push('\n');
            for row in &self.rows {
                s.push_str(&row.join(","));
                s.push('\n');
            }
            if let Err(e) = std::fs::write(&path, s) {
                eprintln!("warn: could not write {path}: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher::quick();
        let s = b.bench("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
        assert!(s.iters > 0);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("demo", &["method", "value"]);
        t.row(&["naive".into(), "1.0".into()]);
        t.row(&["svm".into(), "2.0".into()]);
        let r = t.render();
        assert!(r.contains("naive") && r.contains("svm") && r.contains("demo"));
    }

    #[test]
    fn human_units() {
        assert!(Sample::human(500.0).ends_with("ns"));
        assert!(Sample::human(5_000.0).ends_with("µs"));
        assert!(Sample::human(5_000_000.0).ends_with("ms"));
        assert!(Sample::human(5e9).ends_with(" s"));
    }
}
