//! Serialization substrate: minimal JSON (parse + emit) and CSV writers.
//!
//! The offline environment has no serde; the manifest and bench outputs
//! need only a small, well-tested JSON subset.

mod json;

pub use json::{parse as parse_json, JsonValue};
