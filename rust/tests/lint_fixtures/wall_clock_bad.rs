// Fixture: wall-clock reads are banned in deterministic engine modules.
pub fn elapsed_ms() -> u128 {
    let start = std::time::Instant::now();
    start.elapsed().as_millis()
}

pub fn since_epoch() -> u64 {
    let _ = std::time::SystemTime::now();
    0
}
