//! Trace containers and CSV round-trip.

use crate::linalg::Mat;
use crate::telemetry::catalog::CPU_READY_IDX;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Telemetry trace of one VM: a d × T matrix, one metric vector (column)
/// per 20 s sampling interval, plus identity metadata.
#[derive(Debug, Clone)]
pub struct VmTrace {
    /// Stable VM identifier within its cluster.
    pub vm_id: usize,
    /// Cluster the VM belongs to.
    pub cluster_id: usize,
    /// Workload archetype index (generator-assigned; used as ground truth
    /// for the KMeans pre-clustering experiments).
    pub archetype: usize,
    /// d × T metric matrix (column-major ⇒ each timestep contiguous).
    data: Mat,
    /// Metric names, length d.
    metric_names: Vec<String>,
}

impl VmTrace {
    pub fn new(
        vm_id: usize,
        cluster_id: usize,
        archetype: usize,
        data: Mat,
        metric_names: Vec<String>,
    ) -> Self {
        assert_eq!(data.rows(), metric_names.len());
        Self { vm_id, cluster_id, archetype, data, metric_names }
    }

    /// Feature dimension d.
    pub fn dim(&self) -> usize {
        self.data.rows()
    }

    /// Number of timesteps T.
    pub fn len(&self) -> usize {
        self.data.cols()
    }

    pub fn is_empty(&self) -> bool {
        self.data.cols() == 0
    }

    /// Metric vector at timestep t (a contiguous slice).
    #[inline]
    pub fn features(&self, t: usize) -> &[f64] {
        self.data.col(t)
    }

    /// CPU Ready value (ms per 20 s period) at timestep t.
    #[inline]
    pub fn cpu_ready(&self, t: usize) -> f64 {
        self.data.get(CPU_READY_IDX, t)
    }

    /// The full CPU Ready series.
    pub fn cpu_ready_series(&self) -> Vec<f64> {
        (0..self.len()).map(|t| self.cpu_ready(t)).collect()
    }

    /// The series of metric `idx`.
    pub fn metric_series(&self, idx: usize) -> Vec<f64> {
        assert!(idx < self.dim());
        (0..self.len()).map(|t| self.data.get(idx, t)).collect()
    }

    /// Underlying matrix (d × T).
    pub fn matrix(&self) -> &Mat {
        &self.data
    }

    pub fn metric_names(&self) -> &[String] {
        &self.metric_names
    }

    /// Index of the metric named `name`, if present.
    pub fn metric_index(&self, name: &str) -> Option<usize> {
        self.metric_names.iter().position(|m| m == name)
    }

    /// Sub-trace covering timesteps `[start, end)`.
    pub fn slice(&self, start: usize, end: usize) -> VmTrace {
        assert!(start <= end && end <= self.len());
        let d = self.dim();
        let mut m = Mat::zeros(d, end - start);
        for (jt, t) in (start..end).enumerate() {
            m.col_mut(jt).copy_from_slice(self.data.col(t));
        }
        VmTrace::new(self.vm_id, self.cluster_id, self.archetype, m, self.metric_names.clone())
    }

    /// Write as CSV: header `timestep,<metric...>`, one row per timestep.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        write!(w, "timestep")?;
        for name in &self.metric_names {
            write!(w, ",{name}")?;
        }
        writeln!(w)?;
        for t in 0..self.len() {
            write!(w, "{t}")?;
            for v in self.features(t) {
                write!(w, ",{v:.6}")?;
            }
            writeln!(w)?;
        }
        Ok(())
    }

    /// Read back a CSV produced by [`VmTrace::write_csv`].
    pub fn read_csv(path: &Path, vm_id: usize, cluster_id: usize) -> Result<VmTrace> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut lines = std::io::BufReader::new(f).lines();
        let header = match lines.next() {
            Some(h) => h?,
            None => bail!("empty csv {}", path.display()),
        };
        let names: Vec<String> =
            header.split(',').skip(1).map(|s| s.to_string()).collect();
        if names.is_empty() {
            bail!("csv {} has no metric columns", path.display());
        }
        let d = names.len();
        let mut cols: Vec<f64> = Vec::new();
        let mut t_count = 0usize;
        for line in lines {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let mut parts = line.split(',');
            let _t = parts.next();
            let vals: Vec<f64> = parts
                .map(|s| s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad value {s}: {e}")))
                .collect::<Result<_>>()?;
            if vals.len() != d {
                bail!("row {} has {} values, expected {d}", t_count, vals.len());
            }
            cols.extend_from_slice(&vals);
            t_count += 1;
        }
        let data = Mat::from_col_major(d, t_count, cols);
        Ok(VmTrace::new(vm_id, cluster_id, 0, data, names))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::catalog::vm_metric_names;

    fn tiny_trace() -> VmTrace {
        let names: Vec<String> = vm_metric_names().iter().map(|s| s.to_string()).collect();
        let d = names.len();
        let mut m = Mat::zeros(d, 5);
        for t in 0..5 {
            for i in 0..d {
                m.set(i, t, (t * d + i) as f64 * 0.5);
            }
        }
        VmTrace::new(7, 2, 1, m, names)
    }

    #[test]
    fn accessors() {
        let tr = tiny_trace();
        assert_eq!(tr.dim(), 52);
        assert_eq!(tr.len(), 5);
        assert_eq!(tr.cpu_ready(0), 0.0);
        assert_eq!(tr.cpu_ready(1), 52.0 * 0.5);
        assert_eq!(tr.features(2).len(), 52);
    }

    #[test]
    fn slice_preserves_content() {
        let tr = tiny_trace();
        let s = tr.slice(1, 4);
        assert_eq!(s.len(), 3);
        assert_eq!(s.features(0), tr.features(1));
        assert_eq!(s.features(2), tr.features(3));
    }

    #[test]
    fn csv_roundtrip() {
        let tr = tiny_trace();
        let dir = std::env::temp_dir().join("pronto_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("vm7.csv");
        tr.write_csv(&path).unwrap();
        let back = VmTrace::read_csv(&path, 7, 2).unwrap();
        assert_eq!(back.dim(), tr.dim());
        assert_eq!(back.len(), tr.len());
        for t in 0..tr.len() {
            for i in 0..tr.dim() {
                assert!((back.features(t)[i] - tr.features(t)[i]).abs() < 1e-6);
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn metric_index_finds_named_columns() {
        let tr = tiny_trace();
        assert_eq!(tr.metric_index(&tr.metric_names()[3].clone()), Some(3));
        assert_eq!(tr.metric_index("no.such.metric"), None);
    }

    #[test]
    fn cpu_ready_series_matches_column() {
        let tr = tiny_trace();
        let s = tr.cpu_ready_series();
        assert_eq!(s.len(), 5);
        for (t, v) in s.iter().enumerate() {
            assert_eq!(*v, tr.cpu_ready(t));
        }
    }
}
