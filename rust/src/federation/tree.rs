//! The DASM federation tree (single-threaded engine).

use crate::fpca::{merge_subspaces, MergeOptions, Subspace};

/// Identifier of a tree node (leaves and aggregators share the space).
pub type NodeId = usize;

/// Result of a leaf push.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// The iterate moved less than ε since the last push; nothing sent.
    Suppressed,
    /// The iterate was merged upward through `levels` aggregators.
    Propagated { levels: usize },
}

/// Shape of the federation tree: `q` levels with the given fanout at each
/// internal level. The paper expects "shallow yet very large fan-out".
#[derive(Debug, Clone)]
pub struct TreeTopology {
    /// Number of leaves (compute nodes).
    pub leaves: usize,
    /// Aggregator fanout (children per aggregator).
    pub fanout: usize,
}

impl TreeTopology {
    pub fn new(leaves: usize, fanout: usize) -> Self {
        assert!(leaves >= 1 && fanout >= 2);
        Self { leaves, fanout }
    }

    /// Number of levels above the leaves (root included).
    pub fn levels(&self) -> usize {
        let mut n = self.leaves;
        let mut levels = 0;
        while n > 1 {
            n = n.div_ceil(self.fanout);
            levels += 1;
        }
        levels.max(1)
    }
}

/// One aggregator's state: the merged summary of its subtree.
#[derive(Debug, Clone)]
struct Aggregator {
    summary: Subspace,
    merges: usize,
}

/// The federation tree engine.
///
/// Leaves are external ([`crate::scheduler::NodeScheduler`]s, or anything
/// producing a [`Subspace`]); the tree stores per-leaf "last pushed"
/// snapshots for the ε gate plus one [`Aggregator`] per internal node.
pub struct FederationTree {
    topo: TreeTopology,
    d: usize,
    /// Merge rank used at aggregators.
    rank: usize,
    /// ε threshold of the push gate.
    epsilon: f64,
    /// Last pushed iterate per leaf (None = never pushed).
    last_push: Vec<Option<Subspace>>,
    /// Aggregators per level: `aggs[0]` is the level directly above the
    /// leaves, the last level has a single root.
    aggs: Vec<Vec<Aggregator>>,
    pushes: usize,
    suppressed: usize,
}

impl FederationTree {
    pub fn new(topo: TreeTopology, d: usize, rank: usize, epsilon: f64) -> Self {
        let mut aggs = Vec::new();
        let mut width = topo.leaves;
        loop {
            width = width.div_ceil(topo.fanout);
            aggs.push(vec![
                Aggregator { summary: Subspace::empty(d), merges: 0 };
                width.max(1)
            ]);
            if width <= 1 {
                break;
            }
        }
        Self {
            last_push: vec![None; topo.leaves],
            topo,
            d,
            rank,
            epsilon,
            aggs,
            pushes: 0,
            suppressed: 0,
        }
    }

    pub fn topology(&self) -> &TreeTopology {
        &self.topo
    }

    /// Total pushes that actually propagated.
    pub fn pushes(&self) -> usize {
        self.pushes
    }

    /// Pushes suppressed by the ε gate.
    pub fn suppressed(&self) -> usize {
        self.suppressed
    }

    /// Forget the ε-gate baseline for `leaf` (call when the node behind
    /// the leaf restarts: its first post-rejoin push must not be
    /// suppressed just because the re-learned iterate resembles the
    /// pre-restart one).
    pub fn reset_leaf_gate(&mut self, leaf: NodeId) {
        assert!(leaf < self.topo.leaves);
        self.last_push[leaf] = None;
    }

    /// Leaf `leaf` offers its current iterate. Applies the ε gate, then
    /// merges upward through every ancestor to the root (DASM: summaries
    /// travel up once).
    pub fn push_from_leaf(&mut self, leaf: NodeId, iterate: &Subspace) -> PushOutcome {
        assert!(leaf < self.topo.leaves);
        assert_eq!(iterate.dim(), self.d);
        if iterate.is_empty() {
            return PushOutcome::Suppressed;
        }
        if let Some(prev) = &self.last_push[leaf] {
            if prev.abs_diff(iterate) <= self.epsilon {
                self.suppressed += 1;
                return PushOutcome::Suppressed;
            }
        }
        self.last_push[leaf] = Some(iterate.clone());

        // Walk ancestors: child index at level 0 is the leaf id.
        let mut child = leaf;
        let mut levels = 0;
        for level in 0..self.aggs.len() {
            let parent = child / self.topo.fanout;
            let agg = &mut self.aggs[level][parent];
            agg.summary = merge_subspaces(
                &agg.summary,
                iterate,
                MergeOptions::rank(self.rank),
            );
            agg.merges += 1;
            child = parent;
            levels += 1;
        }
        self.pushes += 1;
        PushOutcome::Propagated { levels }
    }

    /// The merged global view at the root (empty until any push).
    pub fn global_view(&self) -> &Subspace {
        &self.aggs.last().unwrap()[0].summary
    }

    /// The merged view of the level-0 aggregator covering `leaf` — what a
    /// node would pull to seed/refresh its local estimate (§5.2).
    pub fn local_group_view(&self, leaf: NodeId) -> &Subspace {
        &self.aggs[0][leaf / self.topo.fanout].summary
    }

    /// Merge the global view *into* a leaf estimate (the "pull" direction),
    /// returning the refreshed estimate. `forget` down-weights the global
    /// side so a node's own history dominates.
    pub fn pull_global(&self, local: &Subspace, forget: f64) -> Subspace {
        merge_subspaces(
            self.global_view(),
            local,
            MergeOptions { rank: self.rank, forget, enhance: 1.0 },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::subspace_distance;
    use crate::proptest::{gen_low_rank, gen_orthonormal, gen_spectrum};
    use crate::rng::Xoshiro256;

    fn subspace(rng: &mut Xoshiro256, d: usize, r: usize) -> Subspace {
        Subspace::new(gen_orthonormal(rng, d, r), gen_spectrum(rng, r))
    }

    #[test]
    fn topology_levels() {
        assert_eq!(TreeTopology::new(1, 4).levels(), 1);
        assert_eq!(TreeTopology::new(4, 4).levels(), 1);
        assert_eq!(TreeTopology::new(16, 4).levels(), 2);
        assert_eq!(TreeTopology::new(100, 10).levels(), 2);
        assert_eq!(TreeTopology::new(101, 10).levels(), 3);
    }

    #[test]
    fn push_reaches_root() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut tree = FederationTree::new(TreeTopology::new(16, 4), 10, 4, 0.0);
        let s = subspace(&mut rng, 10, 3);
        let out = tree.push_from_leaf(5, &s);
        assert_eq!(out, PushOutcome::Propagated { levels: 2 });
        assert!(!tree.global_view().is_empty());
        assert!(subspace_distance(&tree.global_view().u, &s.u) < 1e-6);
    }

    #[test]
    fn epsilon_gate_suppresses_unchanged_iterates() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut tree = FederationTree::new(TreeTopology::new(8, 4), 10, 4, 0.05);
        let s = subspace(&mut rng, 10, 3);
        assert!(matches!(tree.push_from_leaf(0, &s), PushOutcome::Propagated { .. }));
        // Identical iterate → suppressed.
        assert_eq!(tree.push_from_leaf(0, &s), PushOutcome::Suppressed);
        assert_eq!(tree.suppressed(), 1);
        // A different leaf still propagates.
        assert!(matches!(tree.push_from_leaf(1, &s), PushOutcome::Propagated { .. }));
    }

    #[test]
    fn empty_iterate_never_pushes() {
        let mut tree = FederationTree::new(TreeTopology::new(4, 2), 6, 2, 0.0);
        assert_eq!(
            tree.push_from_leaf(0, &Subspace::empty(6)),
            PushOutcome::Suppressed
        );
    }

    #[test]
    fn global_view_aggregates_shared_structure() {
        // All leaves observe streams drawn from the same rank-2 subspace;
        // the root view should recover that subspace.
        let mut rng = Xoshiro256::seed_from_u64(3);
        let d = 16;
        let shared = gen_low_rank(&mut rng, d, 400, 2, 0.01);
        let truth = crate::linalg::svd_truncated(&shared, 2);

        let mut tree = FederationTree::new(TreeTopology::new(8, 4), d, 4, 0.0);
        for leaf in 0..8 {
            // Each leaf sees a disjoint chunk of the stream.
            let lo = leaf * 50;
            let mut chunk = crate::linalg::Mat::zeros(d, 50);
            for t in 0..50 {
                chunk.col_mut(t).copy_from_slice(shared.col(lo + t));
            }
            let svd = crate::linalg::svd_truncated(&chunk, 2);
            tree.push_from_leaf(leaf, &Subspace::new(svd.u, svd.sigma));
        }
        let dist = subspace_distance(&tree.global_view().truncate(2).u, &truth.u);
        assert!(dist < 0.05, "global view off: {dist}");
    }

    #[test]
    fn local_group_view_scopes_to_subtree() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut tree = FederationTree::new(TreeTopology::new(8, 4), 10, 4, 0.0);
        let s0 = subspace(&mut rng, 10, 2);
        tree.push_from_leaf(0, &s0); // group 0 (leaves 0–3)
        assert!(!tree.local_group_view(1).is_empty());
        assert!(tree.local_group_view(5).is_empty()); // group 1 untouched
    }

    #[test]
    fn pull_global_merges_views() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut tree = FederationTree::new(TreeTopology::new(4, 4), 12, 4, 0.0);
        let remote = subspace(&mut rng, 12, 3);
        tree.push_from_leaf(2, &remote);
        let local = subspace(&mut rng, 12, 3);
        let refreshed = tree.pull_global(&local, 0.5);
        assert_eq!(refreshed.dim(), 12);
        assert!(refreshed.rank() <= 4);
        // Refreshed view is not identical to local: global info arrived.
        assert!(refreshed.abs_diff(&local) > 1e-6);
    }
}
