//! Figure 7: CDFs of per-node downtime (7a) and contained-spike
//! percentage (7b) per embedding method.
//!
//! Paper shape: PRONTO/SP/PM keep very low downtime; FD's downtime
//! exceeds 50 % (≈ a random scheduler). Contained % near or above 100
//! for all methods, with FD skewing high.

use pronto::bench::experiments::{figure67_fleets, ExperimentScale};
use pronto::bench::Table;
use pronto::sim::EvalConfig;

fn main() {
    let scale = ExperimentScale::from_env();
    let fleets = figure67_fleets(&scale, &EvalConfig::default());

    let mut t7a = Table::new(
        "Figure 7a: CDF of per-node downtime %",
        &["downtime%", "PRONTO", "SP", "FD", "PM"],
    );
    let mut down_cdfs: Vec<_> = fleets.iter().map(|f| f.downtime_cdf()).collect();
    for pct in [1.0, 2.0, 5.0, 10.0, 20.0, 30.0, 50.0, 75.0, 100.0] {
        let mut row = vec![format!("{pct}")];
        for cdf in down_cdfs.iter_mut() {
            row.push(format!("{:.3}", cdf.eval(pct)));
        }
        t7a.row(&row);
    }
    t7a.print();
    t7a.maybe_write_csv("fig7a_downtime_cdf");

    let mut t7b = Table::new(
        "Figure 7b: CDF of contained-spike %",
        &["contained%", "PRONTO", "SP", "FD", "PM"],
    );
    let mut cont_cdfs: Vec<_> = fleets.iter().map(|f| f.contained_cdf()).collect();
    for pct in [25.0, 50.0, 75.0, 100.0, 150.0, 200.0, 400.0, 1000.0] {
        let mut row = vec![format!("{pct}")];
        for cdf in cont_cdfs.iter_mut() {
            row.push(format!("{:.3}", cdf.eval(pct)));
        }
        t7b.row(&row);
    }
    t7b.print();
    t7b.maybe_write_csv("fig7b_contained_cdf");

    println!("\nmean downtime per method:");
    for f in &fleets {
        println!("  {:<8} {:.1}%", f.method, 100.0 * f.mean_downtime());
    }
    println!("\nshape: FD downtime should dwarf PRONTO/SP/PM (paper: FD > 50%).");
}
