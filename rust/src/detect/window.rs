//! Sliding-window spike bookkeeping (Figure 5) — the **single owner** of
//! the left/right window semantics.
//!
//! PRONTO classifies rejection-signal raises relative to a *reference
//! point* placed at the middle of a window of size `w` (age `w/2` in
//! steps-back form). With the reference point sitting on a CPU Ready
//! spike, time flows right in the paper's rendering, so:
//!
//! * **Left-sided** raises are *at or before* the spike (ring ages
//!   `>= w/2`): the early warnings. A prediction counts as successful
//!   when a spike is preceded by — or coincides with, per §7 "shortly
//!   before or coincides" — at least one raise inside the left half,
//!   i.e. within the [`left_span`] steps leading up to the spike.
//! * **Right-sided** raises are *after* the spike (ages `< w/2`):
//!   consecutive-spike or delayed detections, within [`right_span`]
//!   steps past it.
//!
//! Historically `sim::eval` carried its own copy of this classification
//! with the opposite orientation from [`SlidingWindow::side_of`]; the
//! timeline helpers below ([`classify_spike`], [`lead_time`],
//! [`raise_true_positive`]) are the shared implementation both the
//! Figure-6/7 evaluation and the prediction-quality scorer consume, so
//! the semantics can no longer fork.

/// Which half of the window an event falls in, relative to the reference
/// point at age w/2 (see Figure 5, third row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpikeSide {
    /// At or before the reference point (ring ages `>= w/2`): raises here
    /// *precede or coincide with* the referenced CPU Ready spike — the
    /// early warnings the paper's success criterion counts.
    Left,
    /// After the reference point (ages `< w/2`): consecutive spikes or
    /// delayed detection.
    Right,
}

/// Counts of events by side within one window evaluation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SideCounts {
    pub left: usize,
    pub right: usize,
}

impl SideCounts {
    pub fn total(&self) -> usize {
        self.left + self.right
    }
}

/// Timesteps *before* the reference spike covered by the left half of a
/// width-`w` window whose reference point sits at `w/2`: the window holds
/// `w - 1 - w/2` steps ahead of the reference in ring-age terms, i.e.
/// earlier in time. A raise up to this many steps before a spike (or
/// coincident with it) predicts it.
pub fn left_span(w: usize) -> usize {
    assert!(w >= 2, "window must hold at least two timesteps");
    w - 1 - w / 2
}

/// Timesteps *after* the reference spike covered by the right half: `w/2`.
pub fn right_span(w: usize) -> usize {
    assert!(w >= 2, "window must hold at least two timesteps");
    w / 2
}

/// Fixed-size boolean ring buffer over the last `w` timesteps with
/// reference-point queries. One instance tracks one binary event stream
/// (e.g. "rejection raised at t" or "CPU Ready spiked at t").
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    w: usize,
    buf: Vec<bool>,
    head: usize,
    seen: usize,
}

impl SlidingWindow {
    pub fn new(w: usize) -> Self {
        assert!(w >= 2, "window must hold at least two timesteps");
        Self { w, buf: vec![false; w], head: 0, seen: 0 }
    }

    /// Window size.
    pub fn len(&self) -> usize {
        self.w
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Observations pushed so far.
    pub fn seen(&self) -> usize {
        self.seen
    }

    /// True once a full window of observations is available — the minimum
    /// before any prediction can be made (Figure 5, second row).
    pub fn full(&self) -> bool {
        self.seen >= self.w
    }

    /// Push the event flag for the newest timestep.
    pub fn push(&mut self, event: bool) {
        self.buf[self.head] = event;
        self.head = (self.head + 1) % self.w;
        self.seen += 1;
    }

    /// Event flag `age` steps back from the newest observation
    /// (`age = 0` is the newest). Panics if `age ≥ min(seen, w)`.
    pub fn get_back(&self, age: usize) -> bool {
        assert!(age < self.w.min(self.seen), "age out of range");
        let idx = (self.head + self.w - 1 - age) % self.w;
        self.buf[idx]
    }

    /// Index (in steps-back form) of the reference point: w/2.
    pub fn reference_age(&self) -> usize {
        self.w / 2
    }

    /// Classify a step-back age into a window side relative to the
    /// reference point. Ages at or older than the reference are `Left` —
    /// they happened *before or at* the reference time, which is where
    /// early warnings live (a coincident raise counts, per §7). Newer
    /// ages are `Right` (after the reference: delayed detections).
    pub fn side_of(&self, age: usize) -> SpikeSide {
        if age >= self.reference_age() {
            SpikeSide::Left
        } else {
            SpikeSide::Right
        }
    }

    /// Count events in the current window by side. Requires a full window.
    pub fn side_counts(&self) -> SideCounts {
        assert!(self.full(), "side_counts needs a full window");
        let mut c = SideCounts::default();
        for age in 0..self.w {
            if self.get_back(age) {
                match self.side_of(age) {
                    SpikeSide::Left => c.left += 1,
                    SpikeSide::Right => c.right += 1,
                }
            }
        }
        c
    }

    /// Any event anywhere in the window?
    pub fn any(&self) -> bool {
        let n = self.w.min(self.seen);
        (0..n).any(|age| self.get_back(age))
    }

    /// Any event within the last `k` observations?
    pub fn any_within(&self, k: usize) -> bool {
        let n = self.w.min(self.seen).min(k);
        (0..n).any(|age| self.get_back(age))
    }
}

/// Figure-5 classification of a raise timeline around one spike at `t`:
/// drive a [`SlidingWindow`] so its reference point lands on the spike
/// (steps `[t - left_span, t + right_span]`, padded with `false` where
/// the timeline ends — a spike at `t = 0` or near the horizon still gets
/// a full window) and split the raises with [`SlidingWindow::side_counts`].
///
/// `left` counts raises in `[t - left_span(w), t]` (early warnings,
/// coincident included); `right` counts raises in `(t, t + right_span(w)]`.
pub fn classify_spike(raised: &[bool], t: usize, w: usize) -> SideCounts {
    let mut win = SlidingWindow::new(w);
    let lo = t as i64 - left_span(w) as i64;
    let hi = t as i64 + right_span(w) as i64;
    for s in lo..=hi {
        let v = s >= 0 && (s as usize) < raised.len() && raised[s as usize];
        win.push(v);
    }
    debug_assert!(win.full());
    win.side_counts()
}

/// Lead time of the spike at `t`: steps from the **first** (earliest)
/// raise inside the left half — `[t - left_span(w), t]` — to the spike.
/// `None` when no raise precedes the spike within the window, i.e. the
/// spike was unpredicted. `Some(0)` is a coincident raise.
pub fn lead_time(raised: &[bool], t: usize, w: usize) -> Option<usize> {
    let lo = t.saturating_sub(left_span(w));
    (lo..=t).find(|&s| s < raised.len() && raised[s]).map(|s| t - s)
}

/// Is the raise at `r` a true positive — does a spike land within its
/// forward window `[r, r + left_span(w)]`? Exactly dual to [`lead_time`]:
/// a spike at `t` is predicted by a raise at `r` iff `0 <= t - r <=
/// left_span(w)`, read from either end.
pub fn raise_true_positive(spikes: &[bool], r: usize, w: usize) -> bool {
    if spikes.is_empty() {
        return false;
    }
    let hi = (r + left_span(w)).min(spikes.len() - 1);
    (r..=hi).any(|s| spikes[s])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_and_wraps() {
        let mut w = SlidingWindow::new(4);
        assert!(!w.full());
        for i in 0..6 {
            w.push(i % 2 == 0);
        }
        assert!(w.full());
        // Last four pushes were for i = 2,3,4,5 → events at ages 1 (i=4) and 3 (i=2).
        assert!(!w.get_back(0)); // i=5
        assert!(w.get_back(1)); // i=4
        assert!(!w.get_back(2)); // i=3
        assert!(w.get_back(3)); // i=2
    }

    #[test]
    fn reference_point_is_half_window() {
        let w = SlidingWindow::new(10);
        assert_eq!(w.reference_age(), 5);
        // Ages at/older than the reference are Left (before the spike —
        // the early-warning half); newer ages are Right (after it).
        assert_eq!(w.side_of(0), SpikeSide::Right);
        assert_eq!(w.side_of(4), SpikeSide::Right);
        assert_eq!(w.side_of(5), SpikeSide::Left);
        assert_eq!(w.side_of(9), SpikeSide::Left);
    }

    #[test]
    fn side_counts_split() {
        let mut w = SlidingWindow::new(6);
        // Push pattern oldest→newest: T F F T F T
        for &e in &[true, false, false, true, false, true] {
            w.push(e);
        }
        // ages: 0=T(newest) 1=F 2=T 3=F 4=F 5=T ; reference_age = 3.
        // Left = ages >= 3 (the oldest half, at/before the reference):
        // only age 5. Right = ages < 3 (after the reference): 0 and 2.
        let c = w.side_counts();
        assert_eq!(c, SideCounts { left: 1, right: 2 });
        assert_eq!(c.total(), 3);
    }

    #[test]
    fn spans_partition_the_window() {
        // left_span + 1 (the spike step) + right_span == w, odd or even.
        for w in 2..=13 {
            assert_eq!(left_span(w) + 1 + right_span(w), w, "w={w}");
        }
        assert_eq!(left_span(10), 4);
        assert_eq!(right_span(10), 5);
        assert_eq!(left_span(11), 5);
        assert_eq!(right_span(11), 5);
        assert_eq!(left_span(2), 0);
        assert_eq!(right_span(2), 1);
    }

    #[test]
    fn classify_spike_matches_manual_counts() {
        // Timeline: raises at 2, 5, 9; spike at 6. w = 10 → left half is
        // [2, 6] (raises 2 and 5), right half is (6, 11] (raise 9).
        let mut raised = vec![false; 12];
        for i in [2, 5, 9] {
            raised[i] = true;
        }
        let c = classify_spike(&raised, 6, 10);
        assert_eq!(c, SideCounts { left: 2, right: 1 });
        // w = 4 → left [5, 6] (raise 5), right (6, 8] (none).
        let c = classify_spike(&raised, 6, 4);
        assert_eq!(c, SideCounts { left: 1, right: 0 });
    }

    #[test]
    fn predicted_iff_left_raise_regression() {
        // Pins the paper's "preceded by ≥1 raise" criterion on both
        // parities of w, at the timeline edge, and for spikes packed
        // closer than half a window — the configurations the historical
        // eval/window orientation split disagreed on.
        // Even w = 10: a raise exactly left_span = 4 steps early predicts…
        let mut raised = vec![false; 40];
        raised[6] = true;
        assert_eq!(lead_time(&raised, 10, 10), Some(4));
        assert!(classify_spike(&raised, 10, 10).left > 0);
        // …but 5 steps early is outside the left half.
        let mut raised = vec![false; 40];
        raised[5] = true;
        assert_eq!(lead_time(&raised, 10, 10), None);
        assert_eq!(classify_spike(&raised, 10, 10).left, 0);
        // Odd w = 11: left_span = 5, so the same raise predicts.
        assert_eq!(lead_time(&raised, 10, 11), Some(5));
        assert!(classify_spike(&raised, 10, 11).left > 0);
        // Spike at t = 0: only a coincident raise can predict it, and the
        // padded window must not panic or wrap.
        let raised = [true, false, false];
        assert_eq!(lead_time(&raised, 0, 10), Some(0));
        assert_eq!(classify_spike(&raised, 0, 10).left, 1);
        let raised = [false, true, false];
        assert_eq!(lead_time(&raised, 0, 10), None);
        // Spikes closer than w/2: one raise between two spikes is
        // right-sided for the first and left-sided for the second.
        let mut raised = vec![false; 20];
        raised[8] = true; // spikes at 7 and 9
        assert_eq!(lead_time(&raised, 7, 10), None);
        assert_eq!(classify_spike(&raised, 7, 10).right, 1);
        assert_eq!(lead_time(&raised, 9, 10), Some(1));
        assert_eq!(classify_spike(&raised, 9, 10).left, 1);
    }

    #[test]
    fn lead_time_reports_first_raise() {
        // Raises at 3 and 5, spike at 6, w = 10: the earliest raise in
        // [2, 6] is at 3 → lead 3 (not the nearer raise at 5).
        let mut raised = vec![false; 10];
        raised[3] = true;
        raised[5] = true;
        assert_eq!(lead_time(&raised, 6, 10), Some(3));
    }

    #[test]
    fn raise_true_positive_is_dual_to_lead_time() {
        let mut spikes = vec![false; 30];
        spikes[10] = true;
        // w = 10 → forward window of a raise spans left_span = 4 steps.
        assert!(raise_true_positive(&spikes, 6, 10));
        assert!(raise_true_positive(&spikes, 10, 10)); // coincident
        assert!(!raise_true_positive(&spikes, 5, 10));
        assert!(!raise_true_positive(&spikes, 11, 10));
        // Past the end of the timeline: no spike, no credit, no panic.
        assert!(!raise_true_positive(&spikes, 29, 10));
        assert!(!raise_true_positive(&[], 0, 10));
    }

    #[test]
    fn any_within_respects_horizon() {
        let mut w = SlidingWindow::new(8);
        for _ in 0..7 {
            w.push(false);
        }
        w.push(true); // newest
        assert!(w.any_within(1));
        for _ in 0..3 {
            w.push(false);
        }
        assert!(!w.any_within(3));
        assert!(w.any_within(4));
    }

    #[test]
    #[should_panic]
    fn side_counts_requires_full_window() {
        let mut w = SlidingWindow::new(4);
        w.push(true);
        let _ = w.side_counts();
    }

    #[test]
    #[should_panic]
    fn spans_reject_degenerate_window() {
        let _ = left_span(1);
    }

    #[test]
    fn get_back_before_full_window() {
        let mut w = SlidingWindow::new(5);
        w.push(true);
        w.push(false);
        assert!(!w.get_back(0));
        assert!(w.get_back(1));
    }
}
