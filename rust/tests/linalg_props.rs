//! Property-based tests for the linear-algebra substrate, using the
//! in-crate `pronto::proptest` harness (seeded, replayable via
//! `PRONTO_PROP_SEED` / `PRONTO_PROP_CASES`).

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::linalg::{
    frob_diff, householder_qr, jacobi_svd, orthonormality_error, subspace_distance,
    svd_truncated, thin_qr, Mat,
};
use pronto::proptest::{forall, gen_low_rank, gen_mat, gen_orthonormal, gen_spectrum};

#[test]
fn qr_q_is_orthonormal_and_reconstructs() {
    forall("QR: QᵀQ = I and QR = A", |rng| {
        let m = 4 + rng.gen_range(24);
        let n = 1 + rng.gen_range(m.min(10));
        let a = gen_mat(rng, m, n);
        let (q, r) = householder_qr(&a);
        let ortho = orthonormality_error(&q);
        if ortho > 1e-9 {
            return Err(format!("Q not orthonormal: {ortho}"));
        }
        let recon = q.matmul(&r);
        let err = frob_diff(&recon, &a) / a.frob_norm().max(1e-12);
        if err > 1e-9 {
            return Err(format!("QR reconstruction error {err}"));
        }
        // R upper-triangular.
        for i in 0..r.rows() {
            for j in 0..i.min(r.cols()) {
                if r.get(i, j).abs() > 1e-9 {
                    return Err(format!("R not triangular at ({i},{j}): {}", r.get(i, j)));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn thin_qr_matches_householder_subspace() {
    forall("thin QR spans the same space", |rng| {
        let m = 6 + rng.gen_range(20);
        let n = 1 + rng.gen_range(6);
        let a = gen_mat(rng, m, n);
        let (q1, _) = householder_qr(&a);
        let (q2, r2) = thin_qr(&a);
        if orthonormality_error(&q2) > 1e-8 {
            return Err("thin Q not orthonormal".into());
        }
        let recon = q2.matmul(&r2);
        let err = frob_diff(&recon, &a) / a.frob_norm().max(1e-12);
        if err > 1e-8 {
            return Err(format!("thin QR reconstruction error {err}"));
        }
        let dist = subspace_distance(&q1, &q2);
        if dist > 1e-7 {
            return Err(format!("QR variants span different spaces: {dist}"));
        }
        Ok(())
    });
}

#[test]
fn svd_reconstructs_low_rank_matrices() {
    forall("SVD: UΣVᵀ ≈ A for low-rank A", |rng| {
        let m = 8 + rng.gen_range(16);
        let n = 8 + rng.gen_range(16);
        let r = 1 + rng.gen_range(4);
        let a = gen_low_rank(rng, m, n, r, 0.0);
        let svd = svd_truncated(&a, r);
        if svd.sigma.windows(2).any(|w| w[0] < w[1]) {
            return Err(format!("sigma not descending: {:?}", svd.sigma));
        }
        if orthonormality_error(&svd.u) > 1e-7 {
            return Err("U not orthonormal".into());
        }
        let recon = svd.u.mul_diag(&svd.sigma).matmul(&svd.v.transpose());
        let err = frob_diff(&recon, &a) / a.frob_norm().max(1e-12);
        if err > 1e-6 {
            return Err(format!("reconstruction error {err} at rank {r}"));
        }
        Ok(())
    });
}

#[test]
fn full_jacobi_svd_reconstructs_general_matrices() {
    forall("Jacobi SVD reconstructs dense A", |rng| {
        let n = 3 + rng.gen_range(10);
        let m = n + rng.gen_range(8); // square-or-tall
        let a = gen_mat(rng, m, n);
        let svd = jacobi_svd(&a);
        let recon = svd.u.mul_diag(&svd.sigma).matmul(&svd.v.transpose());
        let err = frob_diff(&recon, &a) / a.frob_norm().max(1e-12);
        if err > 1e-8 {
            return Err(format!("reconstruction error {err}"));
        }
        if svd.sigma.iter().any(|&s| s < -1e-12) {
            return Err("negative singular value".into());
        }
        Ok(())
    });
}

#[test]
fn subspace_distance_is_a_bounded_symmetric_pseudometric() {
    forall("subspace_distance: symmetry, bounds, identity", |rng| {
        let d = 6 + rng.gen_range(24);
        let r1 = 1 + rng.gen_range(4);
        let r2 = 1 + rng.gen_range(4);
        let u1 = gen_orthonormal(rng, d, r1);
        let u2 = gen_orthonormal(rng, d, r2);
        let d12 = subspace_distance(&u1, &u2);
        let d21 = subspace_distance(&u2, &u1);
        if (d12 - d21).abs() > 1e-9 {
            return Err(format!("asymmetric: {d12} vs {d21}"));
        }
        if !(0.0..=1.0 + 1e-12).contains(&d12) {
            return Err(format!("out of [0,1]: {d12}"));
        }
        let d11 = subspace_distance(&u1, &u1);
        if d11 > 1e-7 {
            return Err(format!("self-distance {d11}"));
        }
        // Invariance to column sign flips.
        let mut flipped = u1.clone();
        for x in flipped.col_mut(0) {
            *x = -*x;
        }
        let dflip = subspace_distance(&u1, &flipped);
        if dflip > 1e-7 {
            return Err(format!("sign flip moved the subspace: {dflip}"));
        }
        Ok(())
    });
}

#[test]
fn subspace_distance_detects_orthogonal_complements() {
    // Deterministic sanity anchor: span(e1) vs span(e2) is maximally far.
    let mut u1 = Mat::zeros(4, 1);
    u1.set(0, 0, 1.0);
    let mut u2 = Mat::zeros(4, 1);
    u2.set(1, 0, 1.0);
    let d = subspace_distance(&u1, &u2);
    assert!((d - 1.0).abs() < 1e-12, "orthogonal spans should be at distance 1: {d}");
}

#[test]
fn spectrum_generator_feeds_valid_subspaces() {
    forall("generated spectra are descending and non-negative", |rng| {
        let r = 1 + rng.gen_range(8);
        let s = gen_spectrum(rng, r);
        if s.len() != r {
            return Err("wrong length".into());
        }
        if s.iter().any(|&x| x < 0.0) {
            return Err("negative sigma".into());
        }
        if s.windows(2).any(|w| w[0] < w[1]) {
            return Err(format!("not descending: {s:?}"));
        }
        Ok(())
    });
}
