//! Householder QR factorization.
//!
//! Used by the subspace merge (Algorithm 4: `QR(U₂ − U₁Z)`) and as the
//! orthonormalization step of the power-method baseline. The thin variant
//! returns Q ∈ ℝ^{m×n}, R ∈ ℝ^{n×n} for m ≥ n, which is all PRONTO needs
//! (merge inputs are tall-skinny, d × r with r ≪ d).

use super::Mat;

/// Thin QR via Householder reflections: `a = Q R` with Q m×n orthonormal
/// columns and R n×n upper triangular. Requires m ≥ n.
///
/// The sign convention makes the diagonal of R non-negative, matching the
/// jnp implementation in `python/compile/linalg.py` so artifacts and native
/// paths agree bit-for-bit up to rounding.
pub fn householder_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "householder_qr requires tall (m >= n) input");
    let mut r = a.clone();
    // Accumulate the reflectors' action on the leading n columns of I.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);

    for k in 0..n {
        // Build the reflector from column k, rows k..m.
        let mut v: Vec<f64> = (k..m).map(|i| r.get(i, k)).collect();
        let norm_x = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm_x > 0.0 {
            let alpha = if v[0] >= 0.0 { -norm_x } else { norm_x };
            v[0] -= alpha;
            let norm_v = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm_v > 0.0 {
                for x in &mut v {
                    *x /= norm_v;
                }
                // Apply (I - 2vvᵀ) to the trailing submatrix of R.
                for j in k..n {
                    let mut dot = 0.0;
                    for (i, &vi) in v.iter().enumerate() {
                        dot += vi * r.get(k + i, j);
                    }
                    for (i, &vi) in v.iter().enumerate() {
                        let cur = r.get(k + i, j);
                        r.set(k + i, j, cur - 2.0 * vi * dot);
                    }
                }
            } else {
                v.clear();
            }
        } else {
            v.clear();
        }
        vs.push(v);
    }

    // Q = H₀ H₁ … H_{n-1} applied to the first n columns of I_m.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        if v.is_empty() {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for (i, &vi) in v.iter().enumerate() {
                dot += vi * q.get(k + i, j);
            }
            for (i, &vi) in v.iter().enumerate() {
                let cur = q.get(k + i, j);
                q.set(k + i, j, cur - 2.0 * vi * dot);
            }
        }
    }

    // Normalize signs so diag(R) >= 0 (uniqueness of the thin QR).
    let mut r_thin = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_thin.set(i, j, r.get(i, j));
        }
    }
    for i in 0..n {
        if r_thin.get(i, i) < 0.0 {
            for j in i..n {
                r_thin.set(i, j, -r_thin.get(i, j));
            }
            for k in 0..m {
                q.set(k, i, -q.get(k, i));
            }
        }
    }
    (q, r_thin)
}

/// Convenience alias used throughout the codebase.
pub fn thin_qr(a: &Mat) -> (Mat, Mat) {
    householder_qr(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{frob_diff, orthonormality_error};
    use crate::rng::Xoshiro256;

    fn random_mat(rng: &mut Xoshiro256, m: usize, n: usize) -> Mat {
        let data: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
        Mat::from_col_major(m, n, data)
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for &(m, n) in &[(4, 4), (10, 3), (50, 8), (7, 1)] {
            let a = random_mat(&mut rng, m, n);
            let (q, r) = householder_qr(&a);
            assert!(frob_diff(&q.matmul(&r), &a) < 1e-9, "m={m} n={n}");
        }
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        for &(m, n) in &[(20, 5), (8, 8), (100, 4)] {
            let a = random_mat(&mut rng, m, n);
            let (q, _) = householder_qr(&a);
            assert!(orthonormality_error(&q) < 1e-10, "m={m} n={n}");
        }
    }

    #[test]
    fn r_is_upper_triangular_nonneg_diag() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let a = random_mat(&mut rng, 12, 6);
        let (_, r) = householder_qr(&a);
        for i in 0..6 {
            assert!(r.get(i, i) >= 0.0);
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn qr_of_rank_deficient_is_finite() {
        // Two identical columns: R gets a ~0 diagonal entry; Q must stay finite.
        let a = Mat::from_rows(4, 2, &[1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 4.0, 4.0]);
        let (q, r) = householder_qr(&a);
        assert!(q.data().iter().all(|x| x.is_finite()));
        assert!(r.get(1, 1).abs() < 1e-9);
        assert!(frob_diff(&q.matmul(&r), &a) < 1e-9);
    }

    #[test]
    fn qr_of_zero_matrix() {
        let a = Mat::zeros(5, 3);
        let (q, r) = householder_qr(&a);
        assert!(q.data().iter().all(|x| x.is_finite()));
        assert_eq!(r, Mat::zeros(3, 3));
    }
}
