//! End-to-end coverage for `pronto lint`: every rule has a bad fixture
//! that fires under a banned virtual path and a good fixture that stays
//! clean, the pragma grammar is exercised in all four states (honored,
//! reason-less, unused, unknown rule), and the whole tree — the crate
//! itself plus `examples/` — must lint clean, which is exactly what the
//! CI job enforces.
//!
//! Fixtures live in `tests/lint_fixtures/` and are fed to the linter as
//! strings under *virtual* paths, so one snippet can be checked against
//! several module classifications. The tree walker skips that directory,
//! keeping the deliberately-bad snippets out of the self-lint.

use pronto::lint::{lint_source, lint_tree, Finding};
use std::path::PathBuf;

fn rules(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

// ---------------------------------------------------------------- wall-clock

#[test]
fn wall_clock_fires_in_banned_module() {
    let src = include_str!("lint_fixtures/wall_clock_bad.rs");
    let findings = lint_source("src/sim/fixture.rs", src);
    assert_eq!(rules(&findings), vec!["wall-clock", "wall-clock"], "{findings:?}");
    assert_eq!(findings[0].line, 3);
    assert_eq!(findings[1].line, 8);
}

#[test]
fn wall_clock_allowed_in_bench_and_cli() {
    let src = include_str!("lint_fixtures/wall_clock_bad.rs");
    assert!(lint_source("src/bench/fixture.rs", src).is_empty());
    assert!(lint_source("src/cli/fixture.rs", src).is_empty());
}

#[test]
fn wall_clock_good_is_clean() {
    let src = include_str!("lint_fixtures/wall_clock_good.rs");
    assert!(lint_source("src/sim/fixture.rs", src).is_empty());
}

// ------------------------------------------------------------ rng-discipline

#[test]
fn rng_discipline_fires_on_raw_mixing_and_literal_tags() {
    let src = include_str!("lint_fixtures/rng_bad.rs");
    let findings = lint_source("src/sim/fixture.rs", src);
    assert_eq!(
        rules(&findings),
        vec!["rng-discipline", "rng-discipline", "rng-discipline"],
        "{findings:?}"
    );
    assert!(findings[0].message.contains("gamma"), "{findings:?}");
    assert!(findings[1].message.contains("SplitMix64"), "{findings:?}");
    assert!(findings[2].message.contains("stream tag"), "{findings:?}");
}

#[test]
fn rng_discipline_good_is_clean() {
    let src = include_str!("lint_fixtures/rng_good.rs");
    assert!(lint_source("src/sim/fixture.rs", src).is_empty());
}

// ------------------------------------------------------------- unordered-iter

#[test]
fn unordered_iter_fires_on_hash_containers() {
    let src = include_str!("lint_fixtures/unordered_bad.rs");
    let findings = lint_source("src/sim/fixture.rs", src);
    assert_eq!(findings.len(), 3, "{findings:?}");
    assert!(findings.iter().all(|f| f.rule == "unordered-iter"));
}

#[test]
fn unordered_iter_good_is_clean() {
    let src = include_str!("lint_fixtures/unordered_good.rs");
    assert!(lint_source("src/sim/fixture.rs", src).is_empty());
}

// -------------------------------------------------------------- env-registry

#[test]
fn env_registry_fires_on_unregistered_key_and_set_var() {
    let src = include_str!("lint_fixtures/env_bad.rs");
    let findings = lint_source("src/sim/fixture.rs", src);
    assert_eq!(rules(&findings), vec!["env-registry", "env-registry"], "{findings:?}");
    assert!(findings[0].message.contains("unregistered env key"), "{findings:?}");
    assert!(findings[1].message.contains("set_var"), "{findings:?}");
}

#[test]
fn env_registry_applies_in_test_paths_too() {
    let src = include_str!("lint_fixtures/env_bad.rs");
    let findings = lint_source("tests/fixture.rs", src);
    assert_eq!(findings.len(), 2, "{findings:?}");
}

#[test]
fn set_var_allowed_only_in_isolated_parity_binaries() {
    let src = include_str!("lint_fixtures/env_bad.rs");
    for path in ["tests/queue_wheel_parity.rs", "tests/linalg_oracle_parity.rs"] {
        let findings = lint_source(path, src);
        // The mutation is waived there; the unregistered key still fires.
        assert_eq!(findings.len(), 1, "{path}: {findings:?}");
        assert!(findings[0].message.contains("unregistered env key"));
    }
}

#[test]
fn env_registry_good_is_clean() {
    let src = include_str!("lint_fixtures/env_good.rs");
    assert!(lint_source("src/sim/fixture.rs", src).is_empty());
}

// -------------------------------------------------------------- unsafe-audit

#[test]
fn unsafe_audit_fires_without_safety_comment() {
    let src = include_str!("lint_fixtures/unsafe_bad.rs");
    let findings = lint_source("src/sim/fixture.rs", src);
    assert_eq!(rules(&findings), vec!["unsafe-audit"], "{findings:?}");
    // Unlike the engine-only rules, this one also applies under tests/
    // and vendor/.
    assert_eq!(lint_source("tests/fixture.rs", src).len(), 1);
    assert_eq!(lint_source("vendor/x/src/lib.rs", src).len(), 1);
}

#[test]
fn unsafe_audit_good_is_clean() {
    let src = include_str!("lint_fixtures/unsafe_good.rs");
    assert!(lint_source("src/sim/fixture.rs", src).is_empty());
}

// ---------------------------------------------------------------- schema-pin

#[test]
fn schema_pin_fires_on_unpinned_keys() {
    let src = include_str!("lint_fixtures/schema_bad.rs");
    let findings = lint_source("src/sim/engine.rs", src);
    assert_eq!(rules(&findings), vec!["schema-pin", "schema-pin"], "{findings:?}");
}

#[test]
fn schema_pin_only_applies_to_pinned_files() {
    let src = include_str!("lint_fixtures/schema_bad.rs");
    assert!(lint_source("src/sim/other.rs", src).is_empty());
}

#[test]
fn schema_pin_good_is_clean() {
    let src = include_str!("lint_fixtures/schema_good.rs");
    assert!(lint_source("src/sim/engine.rs", src).is_empty());
}

// -------------------------------------------------------------------- pragmas

#[test]
fn pragma_with_reason_suppresses() {
    let src = include_str!("lint_fixtures/pragma_ok.rs");
    let findings = lint_source("src/sim/fixture.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn pragma_without_reason_is_rejected_and_suppresses_nothing() {
    let src = include_str!("lint_fixtures/pragma_no_reason.rs");
    let mut got = rules(&lint_source("src/sim/fixture.rs", src));
    got.sort_unstable();
    assert_eq!(got, vec!["pragma", "wall-clock"]);
}

#[test]
fn unused_pragma_is_a_finding() {
    let src = include_str!("lint_fixtures/pragma_unused.rs");
    let findings = lint_source("src/sim/fixture.rs", src);
    assert_eq!(rules(&findings), vec!["pragma"], "{findings:?}");
    assert!(findings[0].message.contains("unused"), "{findings:?}");
}

#[test]
fn pragma_naming_unknown_rule_is_a_finding() {
    let src = include_str!("lint_fixtures/pragma_unknown_rule.rs");
    let findings = lint_source("src/sim/fixture.rs", src);
    assert_eq!(rules(&findings), vec!["pragma"], "{findings:?}");
    assert!(findings[0].message.contains("unknown rule"), "{findings:?}");
}

// ------------------------------------------------------------------ the tree

#[test]
fn whole_tree_lints_clean() {
    let rust = crate_root();
    let examples = rust.join("..").join("examples");
    let report = lint_tree(&[rust, examples]).expect("walking the tree");
    assert!(report.files_scanned > 60, "only scanned {} files", report.files_scanned);
    assert!(report.is_clean(), "tree is not lint-clean:\n{}", report.render_text());
}

#[test]
fn report_json_shape() {
    let src = include_str!("lint_fixtures/unsafe_bad.rs");
    let findings = lint_source("src/sim/fixture.rs", src);
    assert_eq!(findings.len(), 1);
    // The CLI exposes the same findings via `--json`; spot-check the
    // stable field set on the Finding itself.
    assert_eq!(findings[0].rule, "unsafe-audit");
    assert_eq!(findings[0].path, "src/sim/fixture.rs");
    assert!(findings[0].line >= 3);
}

// ---------------------------------------------------------------- CLI wiring

#[test]
fn cli_lint_errors_on_a_dirty_root() {
    let fixture = crate_root().join("tests/lint_fixtures/env_bad.rs");
    let argv = vec!["lint".to_string(), fixture.to_string_lossy().into_owned()];
    let err = pronto::cli::run(&argv).expect_err("env_bad must fail the lint");
    assert!(format!("{err}").contains("finding"), "{err}");
}

#[test]
fn cli_lint_errors_on_unsafe_fixture() {
    let fixture = crate_root().join("tests/lint_fixtures/unsafe_bad.rs");
    let argv = vec![
        "lint".to_string(),
        "--json".to_string(),
        fixture.to_string_lossy().into_owned(),
    ];
    assert!(pronto::cli::run(&argv).is_err());
}

#[test]
fn cli_lint_ok_on_a_clean_subtree() {
    let dir = crate_root().join("src").join("lint");
    let argv = vec!["lint".to_string(), dir.to_string_lossy().into_owned()];
    pronto::cli::run(&argv).expect("src/lint must be lint-clean");
}
