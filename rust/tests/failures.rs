//! Integration coverage for the fault-injection scenario suite: every
//! failure-catalog entry must produce byte-identical reports across
//! observe-pool widths (`--threads 1` vs `4`) *and* across the two
//! trace sources (streaming vs materialized), and the job ledger must
//! conserve exactly under correlated rack churn.

use pronto::scheduler::{Admission, RandomPolicy};
use pronto::sim::{DiscreteEventEngine, Scenario, SimReport};
use pronto::telemetry::{fleet_members, GeneratorConfig, TraceGenerator, TraceSource};

/// Same membership rule as the CLI (`fleet_members`), which is what
/// keeps the two trace sources byte-identical.
const FANOUT: usize = 8;

fn run(name: &str, nodes: usize, steps: usize, threads: usize, stream: bool) -> SimReport {
    let sc = Scenario::named(name)
        .unwrap_or_else(|| panic!("unknown scenario {name}"))
        .with_nodes(nodes)
        .with_steps(steps)
        .with_threads(threads);
    let seed = sc.seed;
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    let members = fleet_members(nodes, FANOUT);
    let source = if stream {
        TraceSource::streaming(&gen, &members, steps, sc.score_window)
    } else {
        let fleet: Vec<_> = members
            .iter()
            .map(|&(c, v)| gen.generate_vm_in_cluster(c, v, steps))
            .collect();
        TraceSource::materialized(fleet)
    };
    let policies: Vec<Box<dyn Admission>> = (0..nodes)
        .map(|i| Box::new(RandomPolicy::always_accept(seed ^ i as u64)) as Box<dyn Admission>)
        .collect();
    DiscreteEventEngine::try_from_source(sc, source, policies)
        .expect("engine builds")
        .run()
}

fn assert_ledger(name: &str, r: &SimReport) {
    let settled = r.jobs_rejected
        + r.jobs_completed
        + r.jobs_dropped
        + r.jobs_displaced
        + r.jobs_still_queued
        + r.jobs_still_running;
    assert_eq!(r.jobs_arrived, settled, "{name}: job ledger leaked");
    assert_eq!(
        r.jobs_arrived,
        r.jobs_accepted + r.jobs_rejected,
        "{name}: accept/reject split leaked"
    );
}

#[test]
fn failure_scenarios_are_byte_stable_across_widths_and_sources() {
    // (scenario, nodes, steps) — sized so every failure mechanism
    // actually fires while the 2×2 grid of runs stays cheap.
    let cases = [
        ("rack-outage", 12, 500),
        ("partition", 8, 500),
        ("straggler", 8, 400),
        ("antagonist", 6, 400),
    ];
    for (name, nodes, steps) in cases {
        let base = run(name, nodes, steps, 1, true);
        let bytes = base.to_json_string();
        assert_ledger(name, &base);
        for (threads, stream) in [(4, true), (1, false), (4, false)] {
            let other = run(name, nodes, steps, threads, stream);
            assert_eq!(
                bytes,
                other.to_json_string(),
                "{name} diverged at threads={threads} stream={stream}"
            );
        }
    }
}

#[test]
fn rack_outage_scenario_churns_and_conserves_under_longer_runs() {
    let r = run("rack-outage", 16, 1_500, 4, true);
    assert!(r.rack_outages > 0, "hazard never fired at this length");
    assert!(r.node_leaves > 0 && r.node_joins > 0, "racks never cycled");
    assert_ledger("rack-outage", &r);
    // The failure keys are part of the serialized surface for failure
    // scenarios — and only for them.
    let text = r.to_json_string();
    assert!(text.contains("\"rack_outages\""));
    let legacy = run("baseline-poisson", 6, 200, 1, true).to_json_string();
    assert!(
        !legacy.contains("rack_outages") && !legacy.contains("antagonist"),
        "legacy reports must not grow failure keys"
    );
}

#[test]
fn antagonist_scenario_reports_tenant_split_consistently() {
    let r = run("antagonist", 6, 600, 1, true);
    assert!(r.antagonist_jobs_arrived > 0, "tenant never arrived");
    assert!(r.antagonist_jobs_arrived < r.jobs_arrived);
    assert!(r.antagonist_jobs_rejected <= r.jobs_rejected);
    assert!(r.antagonist_slo_total <= r.slo_total);
    assert!(r.antagonist_slo_attained <= r.antagonist_slo_total);
    assert_ledger("antagonist", &r);
}
