// Fixture: `unsafe` without a SAFETY comment.
pub fn first(v: &[u8]) -> u8 {
    unsafe { *v.as_ptr() }
}
