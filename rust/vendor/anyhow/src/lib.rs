//! Minimal, API-compatible subset of the `anyhow` crate for the offline
//! build environment (no crates.io access). Implements the surface PRONTO
//! uses: [`Error`], [`Result`], [`Context`], and the `anyhow!` / `bail!` /
//! `ensure!` macros, including `{:#}` chain formatting.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what allows the blanket
//! `From<E: std::error::Error>` conversion to exist without overlapping
//! `From<Error>`.

#![forbid(unsafe_code)]

use std::fmt;

/// An error chain: the outermost message first, each `context` layer
/// wrapping the previous error.
pub struct Error {
    /// Messages from outermost (index 0) to innermost (root cause).
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (the `Context` machinery).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause message (innermost layer).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost first.
            let mut first = true;
            for layer in &self.chain {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{layer}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for layer in &self.chain[1..] {
                write!(f, "\n    {layer}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
        Ok(())
    }

    #[test]
    fn from_std_error_via_question_mark() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn context_chain_formats_alternate() {
        let err = io_fail().context("reading config").unwrap_err();
        let plain = format!("{err}");
        let alt = format!("{err:#}");
        assert_eq!(plain, "reading config");
        assert!(alt.starts_with("reading config: "));
        assert!(alt.len() > plain.len());
    }

    #[test]
    fn with_context_on_option() {
        let none: Option<u32> = None;
        let err = none.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(err.to_string(), "missing thing");
    }

    #[test]
    fn macros_work() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert!(inner(3).is_ok());
        assert_eq!(inner(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(inner(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }
}
