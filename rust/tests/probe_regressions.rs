//! Engine-level regressions for the PR-4 probe fixes, which until now
//! were only unit-tested:
//!
//! * the identity-tracked round-robin cursor — the historical
//!   `cursor % alive_count` re-aliased after churn and could park the
//!   rotation away from surviving hosts, starving them;
//! * the bounded `sample_distinct` sampler's Fisher–Yates fallback at
//!   the `k ≥ alive − 1` boundary (probe fan-outs that want essentially
//!   the whole pool).

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::proptest::forall;
use pronto::rng::Xoshiro256;
use pronto::scheduler::{Admission, JobOutcome, RandomPolicy};
use pronto::sim::{
    sample_distinct, ArrivalPattern, ChurnModel, DiscreteEventEngine, ProbePolicy,
    SampleScratch, Scenario,
};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};

fn fleet(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    (0..n).map(|v| gen.generate_vm_in_cluster(v / 4, v, steps)).collect()
}

fn always(tr: &[VmTrace]) -> Vec<Box<dyn Admission>> {
    tr.iter()
        .enumerate()
        .map(|(i, _)| Box::new(RandomPolicy::always_accept(i as u64)) as Box<dyn Admission>)
        .collect()
}

#[test]
fn round_robin_probes_every_survivor_within_one_rotation_after_churn() {
    // Drain a 6-node fleet to its 3-node floor (aggressive hazard, no
    // rejoin), then check the placement stream's tail: with always-accept
    // policies every arrival is placed on exactly the probed host, so a
    // healthy identity cursor makes every window of `min_alive`
    // consecutive placements a full rotation — `min_alive` *distinct*
    // hosts, the same host set in every window. The aliased index cursor
    // re-aliased on each leave and could starve a survivor (repeats
    // inside a window / a host missing from the tail entirely).
    let min_alive = 3;
    let sc = Scenario {
        probe: ProbePolicy::RoundRobin,
        arrivals: ArrivalPattern::Poisson { rate: 1.2 },
        churn: Some(ChurnModel {
            leave_hazard: 0.5,
            rejoin_delay_mean: 0.0, // leavers never come back
            min_alive,
        }),
        ..Scenario::default()
    }
    .with_nodes(6)
    .with_steps(1_000);
    let tr = fleet(6, 1_000, 41);
    let report = DiscreteEventEngine::new(sc, tr.clone(), always(&tr)).run();
    assert_eq!(
        report.node_leaves,
        6 - min_alive,
        "fleet must drain to the floor for the regression to bite"
    );
    let placed: Vec<usize> = report
        .outcomes
        .iter()
        .filter_map(|o| match o {
            JobOutcome::Accepted { node, .. } => Some(*node),
            _ => None,
        })
        .collect();
    assert!(placed.len() > 200, "load too thin: {}", placed.len());
    // Long tail, far past the churn transient.
    let tail = &placed[placed.len() - 8 * min_alive..];
    let survivor_set = |w: &[usize]| {
        let mut s: Vec<usize> = w.to_vec();
        s.sort_unstable();
        s.dedup();
        s
    };
    let survivors = survivor_set(&tail[..min_alive]);
    assert_eq!(
        survivors.len(),
        min_alive,
        "rotation repeated a host within one lap: {:?}",
        &tail[..min_alive]
    );
    for w in tail.windows(min_alive) {
        assert_eq!(
            survivor_set(w),
            survivors,
            "a survivor was starved out of a rotation window: {w:?}"
        );
    }
}

#[test]
fn probe_fanouts_of_nearly_the_whole_pool_run_deterministically() {
    // k ≥ alive − 1 pushes every arrival's candidate draw into (or right
    // up against) the sampler's Fisher–Yates fallback. The run must stay
    // byte-reproducible and spread work across the whole fleet.
    for k in [5, 6, 8] {
        let sc = Scenario {
            probe: ProbePolicy::PowerOfK(k),
            arrivals: ArrivalPattern::Poisson { rate: 1.0 },
            ..Scenario::default()
        }
        .with_nodes(6)
        .with_steps(600);
        let tr = fleet(6, 600, 43);
        let a = DiscreteEventEngine::new(sc.clone(), tr.clone(), always(&tr)).run();
        let b = DiscreteEventEngine::new(sc, tr.clone(), always(&tr)).run();
        assert_eq!(a.to_json_string(), b.to_json_string(), "k={k} not reproducible");
        let mut seen = [false; 6];
        for o in &a.outcomes {
            if let JobOutcome::Accepted { node, .. } = o {
                seen[*node] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "k={k} starved a host: {seen:?}");
    }
}

#[test]
fn sample_distinct_is_complete_at_the_fallback_boundary() {
    forall("sample_distinct: k ∈ {avail−1, avail, avail+3}", |rng| {
        let pool_len = 2 + rng.gen_range(11);
        let pool: Vec<usize> = (0..pool_len * 3).step_by(3).collect(); // sparse ids
        let exclude = if rng.bernoulli(0.5) {
            Some(pool[rng.gen_range(pool_len)])
        } else {
            None
        };
        let avail = pool_len - usize::from(exclude.is_some());
        let mut out = Vec::new();
        let mut scratch = SampleScratch::default();
        for want in [avail.saturating_sub(1), avail, avail + 3] {
            let mut a = Xoshiro256::seed_from_u64(rng.next_u64());
            let mut b = a.clone();
            sample_distinct(&mut a, &pool, exclude, want, &mut out, &mut scratch);
            let expect = want.min(avail);
            if out.len() != expect {
                return Err(format!(
                    "want {want} of {avail} available returned {} (pool {pool_len})",
                    out.len()
                ));
            }
            let mut sorted = out.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != out.len() {
                return Err(format!("duplicates in draw: {out:?}"));
            }
            if let Some(e) = exclude {
                if out.contains(&e) {
                    return Err(format!("excluded id {e} drawn: {out:?}"));
                }
            }
            if out.iter().any(|c| !pool.contains(c)) {
                return Err(format!("drew an id outside the pool: {out:?}"));
            }
            // Same RNG state ⇒ same draw (the determinism the engine's
            // byte contract rests on).
            let mut again = Vec::new();
            sample_distinct(&mut b, &pool, exclude, want, &mut again, &mut scratch);
            if again != out {
                return Err("draw not deterministic under a cloned RNG".into());
            }
        }
        Ok(())
    });
}

#[test]
fn sample_distinct_dense_draws_are_permutations_across_seeds() {
    // The guaranteed-fallback shape: want == |pool| with a pool large
    // enough that rejection sampling cannot finish inside its budget, so
    // the Fisher–Yates completion must deliver the rest — for every
    // seed, not just the one the unit test happens to use.
    let pool: Vec<usize> = (0..96).collect();
    let mut out = Vec::new();
    let mut scratch = SampleScratch::default();
    for seed in 0..200u64 {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        sample_distinct(&mut rng, &pool, None, pool.len(), &mut out, &mut scratch);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, pool, "seed {seed}: dense draw is not a permutation");
    }
}

#[test]
fn sample_distinct_scales_to_hundred_k_alive_sets() {
    // The 100k-node audit. Two historical hazards at this scale:
    //
    // * the membership test inside the rejection loop and the fallback
    //   filter used to scan the pool/draw (`out.contains`, a linear probe
    //   per candidate) — quadratic once `want` tracks the pool size, which
    //   turned a single dense 100k draw into ~10^10 comparisons. The
    //   stamp-epoch scratch makes both O(1) per candidate, so this test
    //   finishes in milliseconds where the old code would hang.
    // * the `4·want + 8` rejection budget collapsing for tiny `want`
    //   against a huge alive set — the sparse draw below must still fill
    //   from rejection sampling or complete exactly via the fallback.
    let n = 100_000;
    let pool: Vec<usize> = (0..n).collect();
    let mut out = Vec::new();
    let mut scratch = SampleScratch::default();

    // Sparse fan-out (the PowerOfK hot path at fleet scale).
    let mut rng = Xoshiro256::seed_from_u64(9);
    let mut twin = rng.clone();
    sample_distinct(&mut rng, &pool, Some(17), 8, &mut out, &mut scratch);
    assert_eq!(out.len(), 8);
    let mut sorted = out.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), 8, "duplicates in sparse draw: {out:?}");
    assert!(!out.contains(&17), "excluded id drawn");
    let mut again = Vec::new();
    sample_distinct(&mut twin, &pool, Some(17), 8, &mut again, &mut scratch);
    assert_eq!(again, out, "sparse 100k draw not deterministic");

    // Dense draw: the guaranteed Fisher–Yates fallback at 100k.
    let mut rng = Xoshiro256::seed_from_u64(10);
    sample_distinct(&mut rng, &pool, None, n, &mut out, &mut scratch);
    assert_eq!(out.len(), n);
    let mut sorted = out.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, pool, "dense 100k draw is not a permutation");

    // Back-to-back reuse of the same scratch (epoch bump, no clearing)
    // must not leak stamps between draws.
    let mut rng = Xoshiro256::seed_from_u64(11);
    sample_distinct(&mut rng, &pool, None, 5, &mut out, &mut scratch);
    assert_eq!(out.len(), 5);
}

#[test]
fn round_robin_cursor_survives_mass_churn_at_scale() {
    // Engine-level companion to the FleetState unit tests: a 48-node
    // fleet drained to a 4-node floor under heavy hazard while the
    // round-robin cursor keeps rotating. Every window of `min_alive`
    // consecutive tail placements must be a full rotation over the same
    // survivor set — cursor drift under mass leave/join (rank-shift bugs
    // in the dense alive index) shows up as repeats or starvation.
    let min_alive = 4;
    let nodes = 48;
    let sc = Scenario {
        probe: ProbePolicy::RoundRobin,
        arrivals: ArrivalPattern::Poisson { rate: 1.5 },
        churn: Some(ChurnModel {
            leave_hazard: 0.6,
            rejoin_delay_mean: 0.0, // leavers never come back
            min_alive,
        }),
        ..Scenario::default()
    }
    .with_nodes(nodes)
    .with_steps(1_200);
    let tr = fleet(nodes, 1_200, 47);
    let report = DiscreteEventEngine::new(sc, tr.clone(), always(&tr)).run();
    assert_eq!(
        report.node_leaves,
        nodes - min_alive,
        "fleet must drain to the floor for the regression to bite"
    );
    let placed: Vec<usize> = report
        .outcomes
        .iter()
        .filter_map(|o| match o {
            JobOutcome::Accepted { node, .. } => Some(*node),
            _ => None,
        })
        .collect();
    assert!(placed.len() > 300, "load too thin: {}", placed.len());
    let tail = &placed[placed.len() - 10 * min_alive..];
    let survivor_set = |w: &[usize]| {
        let mut s: Vec<usize> = w.to_vec();
        s.sort_unstable();
        s.dedup();
        s
    };
    let survivors = survivor_set(&tail[..min_alive]);
    assert_eq!(survivors.len(), min_alive, "rotation repeated a host: {:?}", &tail[..min_alive]);
    for w in tail.windows(min_alive) {
        assert_eq!(survivor_set(w), survivors, "survivor starved out of a window: {w:?}");
    }
}
