//! The §7.1 evaluation: rejection signal vs CPU Ready ground truth.
//!
//! For every CPU Ready spike in a VM's trace we examine the rejection
//! signal inside a window of size `w` whose reference point sits on the
//! spike at age `w/2` (Figure 5): raises in the half *before* the spike
//! are **left-sided** (successful early warnings — "a CPU Ready spike is
//! preceded by at least one rejection raise"), raises in the half after
//! are **right-sided** (consecutive-spike or delayed detections). The
//! classification itself lives in [`crate::detect::window`]
//! ([`classify_spike`] / [`lead_time`]) so this module and the
//! prediction-quality scorer ([`crate::sim::quality`]) share one
//! implementation. We also record the signal's **downtime** (fraction of
//! time raised — lost admission capacity) and the **contained-spike
//! percentage** (rejection raises per CPU Ready spike; >100 % ⇒ the
//! method raises more often than the ground truth spikes — Figure 7's
//! over-rejection axis).

use crate::baselines::StreamingEmbedding;
use crate::detect::window::{classify_spike, lead_time};
use crate::metrics::EmpiricalCdf;
use crate::scheduler::{NodeScheduler, RejectConfig};
use crate::telemetry::VmTrace;

/// Evaluation parameters (paper defaults: w = 10, CPU Ready spike at the
/// μ+3σ-like fixed level of the trace generator's calibration).
#[derive(Debug, Clone, Copy)]
pub struct EvalConfig {
    /// Sliding-window size w (timesteps). Paper: ~10, range 10–50.
    pub window: usize,
    /// CPU Ready spike threshold (ms per 20 s period).
    pub ready_threshold: f64,
    /// Reject-Job configuration.
    pub reject: RejectConfig,
}

impl Default for EvalConfig {
    fn default() -> Self {
        Self {
            window: 10,
            ready_threshold: 1000.0,
            reject: RejectConfig::default(),
        }
    }
}

/// Per-VM evaluation result.
#[derive(Debug, Clone)]
pub struct NodeEvaluation {
    /// Method tag.
    pub method: &'static str,
    /// CPU Ready spikes in the trace.
    pub ready_spikes: usize,
    /// Rejection-signal raises.
    pub rejection_raises: usize,
    /// Per-spike left-sided raise counts.
    pub left_counts: Vec<usize>,
    /// Per-spike right-sided raise counts.
    pub right_counts: Vec<usize>,
    /// Per-spike lead time: steps from the earliest left-sided raise to
    /// the spike (`Some(0)` = coincident raise, `None` = unpredicted).
    /// Aligned with `left_counts`/`right_counts`.
    pub lead_times: Vec<Option<usize>>,
    /// Fraction of timesteps with the signal raised.
    pub downtime: f64,
    /// Total trace length.
    pub steps: usize,
}

impl NodeEvaluation {
    /// Spikes predicted by ≥1 left-sided raise (the success criterion).
    pub fn predicted_spikes(&self) -> usize {
        self.left_counts.iter().filter(|&&c| c > 0).count()
    }

    /// Prediction rate over all CPU Ready spikes.
    pub fn prediction_rate(&self) -> f64 {
        if self.ready_spikes == 0 {
            1.0
        } else {
            self.predicted_spikes() as f64 / self.ready_spikes as f64
        }
    }

    /// Contained-spike percentage (Figure 7b): rejection raises relative
    /// to CPU Ready spikes, in percent (can exceed 100).
    pub fn contained_pct(&self) -> f64 {
        if self.ready_spikes == 0 {
            0.0
        } else {
            100.0 * self.rejection_raises as f64 / self.ready_spikes as f64
        }
    }
}

/// Evaluate one embedding method over one VM trace.
///
/// Drives a [`NodeScheduler`] over the trace, collects the per-timestep
/// rejection signal, then post-hoc classifies raises around every CPU
/// Ready spike.
pub fn evaluate_method<E: StreamingEmbedding>(
    embedding: E,
    trace: &VmTrace,
    cfg: &EvalConfig,
) -> NodeEvaluation {
    let mut node = NodeScheduler::with_embedding(embedding, cfg.reject);
    let t_len = trace.len();
    let mut raised = vec![false; t_len];
    for t in 0..t_len {
        node.observe(trace.features(t));
        raised[t] = node.rejection_raised();
    }
    let method = node.method();

    let mut left_counts = Vec::new();
    let mut right_counts = Vec::new();
    let mut lead_times = Vec::new();
    let mut ready_spikes = 0usize;
    for t in 0..t_len {
        if trace.cpu_ready(t) < cfg.ready_threshold {
            continue;
        }
        ready_spikes += 1;
        let sides = classify_spike(&raised, t, cfg.window);
        left_counts.push(sides.left);
        right_counts.push(sides.right);
        lead_times.push(lead_time(&raised, t, cfg.window));
    }

    NodeEvaluation {
        method,
        ready_spikes,
        rejection_raises: raised.iter().filter(|&&r| r).count(),
        left_counts,
        right_counts,
        lead_times,
        downtime: node.stats().downtime(),
        steps: t_len,
    }
}

/// Aggregated fleet evaluation for one method: the CDF inputs of
/// Figures 6 and 7.
#[derive(Debug)]
pub struct FleetEvaluation {
    pub method: &'static str,
    pub nodes: Vec<NodeEvaluation>,
}

impl FleetEvaluation {
    pub fn new(method: &'static str) -> Self {
        Self { method, nodes: Vec::new() }
    }

    pub fn push(&mut self, eval: NodeEvaluation) {
        assert_eq!(eval.method, self.method);
        self.nodes.push(eval);
    }

    /// CDF over all spikes of left-sided raise counts (Figure 6a).
    pub fn left_cdf(&self) -> EmpiricalCdf {
        let mut c = EmpiricalCdf::new();
        for n in &self.nodes {
            for &x in &n.left_counts {
                c.push(x as f64);
            }
        }
        c
    }

    /// CDF over all spikes of right-sided raise counts (Figure 6b).
    pub fn right_cdf(&self) -> EmpiricalCdf {
        let mut c = EmpiricalCdf::new();
        for n in &self.nodes {
            for &x in &n.right_counts {
                c.push(x as f64);
            }
        }
        c
    }

    /// CDF over nodes of downtime percentage (Figure 7a).
    pub fn downtime_cdf(&self) -> EmpiricalCdf {
        let mut c = EmpiricalCdf::new();
        for n in &self.nodes {
            c.push(100.0 * n.downtime);
        }
        c
    }

    /// CDF over nodes of contained-spike percentage (Figure 7b).
    pub fn contained_cdf(&self) -> EmpiricalCdf {
        let mut c = EmpiricalCdf::new();
        for n in &self.nodes {
            c.push(n.contained_pct());
        }
        c
    }

    /// Fleet-level mean prediction rate.
    pub fn mean_prediction_rate(&self) -> f64 {
        if self.nodes.is_empty() {
            return 0.0;
        }
        self.nodes.iter().map(NodeEvaluation::prediction_rate).sum::<f64>()
            / self.nodes.len() as f64
    }

    /// Fleet-level mean downtime.
    pub fn mean_downtime(&self) -> f64 {
        if self.nodes.is_empty() {
            return 0.0;
        }
        self.nodes.iter().map(|n| n.downtime).sum::<f64>() / self.nodes.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpca::{FpcaEdge, FpcaEdgeConfig};
    use crate::telemetry::{GeneratorConfig, TraceGenerator};

    fn trace(seed: u64, steps: usize) -> VmTrace {
        TraceGenerator::new(GeneratorConfig::default(), seed).generate_vm(0, steps)
    }

    fn fpca(d: usize) -> FpcaEdge {
        FpcaEdge::new(d, FpcaEdgeConfig::default())
    }

    #[test]
    fn evaluation_counts_are_consistent() {
        let tr = trace(21, 4000);
        let ev = evaluate_method(fpca(tr.dim()), &tr, &EvalConfig::default());
        assert_eq!(ev.method, "PRONTO");
        assert_eq!(ev.steps, 4000);
        assert_eq!(ev.left_counts.len(), ev.ready_spikes);
        assert_eq!(ev.right_counts.len(), ev.ready_spikes);
        assert_eq!(ev.lead_times.len(), ev.ready_spikes);
        assert!(ev.ready_spikes > 0, "calibrated trace must contain spikes");
        assert!((0.0..=1.0).contains(&ev.downtime));
        // Side counts bounded by the window-half spans.
        let w = EvalConfig::default().window;
        let left_max = crate::detect::window::left_span(w) + 1;
        let right_max = crate::detect::window::right_span(w);
        assert!(ev.left_counts.iter().all(|&c| c <= left_max));
        assert!(ev.right_counts.iter().all(|&c| c <= right_max));
        // A spike has a lead time iff it has a left-sided raise, and the
        // lead never exceeds the left span.
        for (lc, lt) in ev.left_counts.iter().zip(&ev.lead_times) {
            assert_eq!(*lc > 0, lt.is_some());
            if let Some(l) = lt {
                assert!(*l <= crate::detect::window::left_span(w));
            }
        }
    }

    #[test]
    fn pronto_predicts_precursor_spikes() {
        // With the generator's precursor structure, PRONTO should predict
        // a solid fraction of spikes while keeping downtime low.
        let tr = trace(33, 12_000);
        let ev = evaluate_method(fpca(tr.dim()), &tr, &EvalConfig::default());
        assert!(
            ev.prediction_rate() > 0.3,
            "prediction rate too low: {:.3} over {} spikes",
            ev.prediction_rate(),
            ev.ready_spikes
        );
        assert!(ev.downtime < 0.4, "downtime too high: {:.3}", ev.downtime);
    }

    #[test]
    fn fleet_cdfs_have_all_samples() {
        let cfg = EvalConfig::default();
        let mut fleet = FleetEvaluation::new("PRONTO");
        let mut total_spikes = 0;
        for seed in 0..3u64 {
            let tr = trace(seed, 3000);
            let ev = evaluate_method(fpca(tr.dim()), &tr, &cfg);
            total_spikes += ev.ready_spikes;
            fleet.push(ev);
        }
        assert_eq!(fleet.left_cdf().len(), total_spikes);
        assert_eq!(fleet.downtime_cdf().len(), 3);
        assert!(fleet.mean_prediction_rate() > 0.0);
    }

    #[test]
    fn oracle_like_signal_scores_perfectly() {
        // A synthetic evaluation where the rejection signal IS the spike
        // indicator shifted one step early: every spike predicted, via
        // the canonical window classification.
        let tr = trace(5, 2000);
        let threshold = 1000.0;
        let t_len = tr.len();
        let mut raised = vec![false; t_len];
        for t in 1..t_len {
            if tr.cpu_ready(t) >= threshold {
                raised[t - 1] = true;
            }
        }
        let w = 10usize;
        let mut predicted = 0;
        let mut spikes = 0;
        // Start at 1: a spike at step 0 has no earlier step for the
        // shifted indicator to land on.
        for t in 1..t_len {
            if tr.cpu_ready(t) < threshold {
                continue;
            }
            spikes += 1;
            if classify_spike(&raised, t, w).left > 0 {
                predicted += 1;
                // Every predicted spike carries a lead time (clustered
                // spikes can inherit an earlier neighbour's raise, so the
                // exact value is pinned in tests/eval_quality.rs on a
                // well-spaced synthetic timeline instead).
                assert!(lead_time(&raised, t, w).is_some());
            }
        }
        assert_eq!(predicted, spikes);
    }
}
