//! Shared experiment drivers: the workloads behind every paper table and
//! figure, used by both `rust/benches/*` and the `pronto bench-tables` CLI.
//!
//! Each driver is deterministic given its seed and returns plain row data;
//! rendering (text table / CSV) happens at the call site.

use crate::baselines::{BlockPowerMethod, FrequentDirections, Spirit, SpiritConfig};
use crate::forecast::{
    alarm_forecast_accuracy, Arima, DistanceKind, ExpSmoothing, Forecaster, KMeansSeries,
    LinearSvr, Naive, SpikeThreshold,
};
use crate::fpca::{FpcaEdge, FpcaEdgeConfig};
use crate::metrics::rmse;
use crate::sim::{evaluate_method, EvalConfig, FleetEvaluation};
use crate::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};

/// Scale knobs for the experiment suite. `quick()` keeps `make test`-level
/// smoke runs fast; `paper()` is the full evaluation scale.
#[derive(Debug, Clone, Copy)]
pub struct ExperimentScale {
    /// VMs per cluster in forecasting experiments.
    pub vms_per_cluster: usize,
    /// Clusters sampled.
    pub clusters: usize,
    /// Steps per day used when aggregating to daily granularity.
    pub steps_per_day: usize,
    /// History days for the long-window experiments.
    pub history_days: usize,
    /// Fleet size for the Figure 6/7 evaluation.
    pub fleet: usize,
    /// Trace length for the fleet evaluation.
    pub fleet_steps: usize,
    pub seed: u64,
}

impl ExperimentScale {
    pub fn quick() -> Self {
        Self {
            vms_per_cluster: 4,
            clusters: 2,
            steps_per_day: 144, // 10-minute cadence stand-in for speed
            history_days: 21,
            fleet: 8,
            fleet_steps: 4_000,
            seed: 0xBEEF,
        }
    }

    pub fn paper() -> Self {
        Self {
            vms_per_cluster: 12,
            clusters: 3,
            steps_per_day: 288,
            history_days: 21,
            fleet: 48,
            fleet_steps: 12_000,
            seed: 2021,
        }
    }

    /// Honour `PRONTO_BENCH_QUICK=1`.
    pub fn from_env() -> Self {
        if std::env::var("PRONTO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
            Self::quick()
        } else {
            Self::paper()
        }
    }
}

/// Daily median CPU Ready series for a VM (Tables 1–2 forecast daily
/// medians).
pub fn daily_medians(trace: &VmTrace, steps_per_day: usize) -> Vec<f64> {
    let days = trace.len() / steps_per_day;
    let mut out = Vec::with_capacity(days);
    for d in 0..days {
        let mut vals: Vec<f64> = (d * steps_per_day..(d + 1) * steps_per_day)
            .map(|t| trace.cpu_ready(t))
            .collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.push(vals[vals.len() / 2]);
    }
    out
}

/// The forecasting method set of §3.1 (Tables 1 and 3).
pub fn standard_methods() -> Vec<Box<dyn Forecaster>> {
    vec![
        Box::new(Naive),
        Box::new(ExpSmoothing::default()),
        Box::new(Arima::default()),
        Box::new(LinearSvr::default()),
    ]
}

/// Generate the per-cluster daily-median panels for Tables 1–2: for each
/// cluster, (per-VM daily median series, archetypes).
pub fn median_panels(scale: &ExperimentScale) -> Vec<Vec<Vec<f64>>> {
    let total_days = scale.history_days + 1; // history + 1 forecast day
    let steps = total_days * scale.steps_per_day;
    let gen = TraceGenerator::new(GeneratorConfig::default(), scale.seed);
    (0..scale.clusters)
        .map(|c| {
            (0..scale.vms_per_cluster)
                .map(|v| {
                    let tr = gen.generate_vm_in_cluster(c, v, steps);
                    daily_medians(&tr, scale.steps_per_day)
                })
                .collect()
        })
        .collect()
}

/// Table 1 row: average RMSE predicting the next day's median per VM,
/// using (same-VM history) vs (same-cluster pool), for 14/21-day windows.
pub fn table1_rmse(scale: &ExperimentScale) -> Vec<(String, [f64; 4])> {
    let panels = median_panels(scale);
    let methods = standard_methods();
    let mut rows = Vec::new();
    for m in &methods {
        let mut cells = [0.0f64; 4];
        for (ci, &(window, pooled)) in
            [(14usize, false), (21, false), (14, true), (21, true)].iter().enumerate()
        {
            let mut errs = Vec::new();
            for cluster in &panels {
                for (vi, series) in cluster.iter().enumerate() {
                    if series.len() < window + 1 {
                        continue;
                    }
                    let hist = &series[series.len() - 1 - window..series.len() - 1];
                    let truth = [series[series.len() - 1]];
                    let pool_vecs: Vec<&[f64]> = if pooled {
                        cluster
                            .iter()
                            .enumerate()
                            .filter(|(vj, s)| *vj != vi && s.len() >= window + 1)
                            .map(|(_, s)| &s[s.len() - 1 - window..s.len() - 1])
                            .collect()
                    } else {
                        Vec::new()
                    };
                    let fc = m.forecast(hist, &pool_vecs, 1);
                    errs.push(rmse(&fc, &truth));
                }
            }
            cells[ci] = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
        }
        rows.push((m.name().to_string(), cells));
    }
    rows
}

/// Table 2: SVM forecasting pooled over "similar VMs" from KMeans
/// pre-clustering under each distance, plus the plain "Ordered"
/// (nearest-by-euclidean) baseline. Returns (row label, [rmse14, rmse21]).
pub fn table2_clustering(scale: &ExperimentScale) -> Vec<(String, [f64; 2])> {
    let panels = median_panels(scale);
    // Flatten VMs across clusters: Table 2 pools "similar" VMs fleet-wide.
    let all: Vec<Vec<f64>> = panels.into_iter().flatten().collect();
    let svr = LinearSvr::default();

    let mut rows: Vec<(String, [f64; 2])> = Vec::new();
    let mut eval = |label: String, similar: &dyn Fn(usize, usize) -> Vec<usize>| {
        let mut cells = [0.0f64; 2];
        for (ci, &window) in [14usize, 21].iter().enumerate() {
            let mut errs = Vec::new();
            for (vi, series) in all.iter().enumerate() {
                if series.len() < window + 1 {
                    continue;
                }
                let hist = &series[series.len() - 1 - window..series.len() - 1];
                let truth = [series[series.len() - 1]];
                let sim = similar(vi, window);
                let pool_vecs: Vec<&[f64]> = sim
                    .iter()
                    .filter(|&&vj| all[vj].len() >= window + 1)
                    .map(|&vj| &all[vj][all[vj].len() - 1 - window..all[vj].len() - 1])
                    .collect();
                let fc = svr.forecast(hist, &pool_vecs, 1);
                errs.push(rmse(&fc, &truth));
            }
            cells[ci] = errs.iter().sum::<f64>() / errs.len().max(1) as f64;
        }
        rows.push((label, cells));
    };

    // "Ordered": nearest half of the fleet by euclidean distance.
    let all_ref = &all;
    eval("Ordered".to_string(), &|vi, window| {
        let mut d: Vec<(usize, f64)> = all_ref
            .iter()
            .enumerate()
            .filter(|(vj, _)| *vj != vi)
            .map(|(vj, s)| {
                let w = window.min(s.len() - 1).min(all_ref[vi].len() - 1);
                let a = &all_ref[vi][all_ref[vi].len() - 1 - w..all_ref[vi].len() - 1];
                let b = &s[s.len() - 1 - w..s.len() - 1];
                (vj, DistanceKind::Euclidean.distance(a, b))
            })
            .collect();
        d.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        d.truncate((all_ref.len() / 2).max(1));
        d.into_iter().map(|(j, _)| j).collect()
    });

    for kind in [
        DistanceKind::Euclidean,
        DistanceKind::Correlation,
        DistanceKind::Sts,
        DistanceKind::Cort,
        DistanceKind::Acf,
    ] {
        let k = (all.len() / 4).clamp(2, 6);
        let km = KMeansSeries::new(k, kind);
        let all2 = all.clone();
        eval(kind.name().to_string(), &move |vi, _| {
            // Cluster on the full (minus last day) series.
            let series: Vec<Vec<f64>> = all2
                .iter()
                .map(|s| s[..s.len() - 1].to_vec())
                .collect();
            km.similar_to(&series, vi, 1)
        });
    }
    rows
}

/// Table 3: RMSE per forecasting-window duration; past window = forecast
/// window (§3.1). Durations in steps at the 20 s cadence.
pub fn table3_windows(scale: &ExperimentScale) -> (Vec<&'static str>, Vec<(String, Vec<f64>)>) {
    // 1 day, 12 h, 6 h, 3 h, 1 h, 30 min, 15 min — in 20 s steps, scaled
    // down by the quick-mode cadence factor.
    let day = scale.steps_per_day;
    let windows: Vec<usize> = vec![
        day,
        day / 2,
        day / 4,
        day / 8,
        (day / 24).max(4),
        (day / 48).max(3),
        (day / 96).max(2),
    ];
    let labels = vec!["1 day", "12 hours", "6 hours", "3 hours", "1 hour", "30 min", "15 min"];

    let steps = 3 * day + 2 * windows[0];
    let gen = TraceGenerator::new(GeneratorConfig::default(), scale.seed ^ 0x3);
    let traces: Vec<Vec<Vec<f64>>> = (0..scale.clusters)
        .map(|c| {
            (0..scale.vms_per_cluster)
                .map(|v| gen.generate_vm_in_cluster(c, v, steps).cpu_ready_series())
                .collect()
        })
        .collect();

    let methods = standard_methods();
    let mut rows = Vec::new();
    for m in &methods {
        let mut cells = Vec::with_capacity(windows.len());
        for &w in &windows {
            let mut errs = Vec::new();
            for cluster in &traces {
                // Aggregate each VM's trace into a per-window median
                // series — the Tables 1–3 protocol ("predict the average
                // values for long forecasting windows", Q3). Long windows
                // give smooth targets; short windows degenerate toward
                // raw (spiky) values, which is why the paper's RMSE blows
                // up as the window shrinks.
                let med_series: Vec<Vec<f64>> = cluster
                    .iter()
                    .map(|series| {
                        series
                            .chunks_exact(w)
                            .map(|chunk| {
                                let mut v = chunk.to_vec();
                                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                                v[v.len() / 2]
                            })
                            .collect()
                    })
                    .collect();
                let m_len = med_series[0].len();
                let evals = (m_len / 4).clamp(1, 6);
                for (vi, med) in med_series.iter().enumerate() {
                    for k in 0..evals {
                        let end = m_len - k;
                        if end < 4 {
                            break;
                        }
                        let hist = &med[..end - 1];
                        let truth = [med[end - 1]];
                        let pool_vecs: Vec<&[f64]> = med_series
                            .iter()
                            .enumerate()
                            .filter(|(vj, _)| *vj != vi)
                            .map(|(_, s)| &s[..end - 1])
                            .collect();
                        let fc = m.forecast(hist, &pool_vecs, 1);
                        errs.push(rmse(&fc, &truth));
                    }
                }
            }
            let mse = errs.iter().map(|e| e * e).sum::<f64>() / errs.len().max(1) as f64;
            cells.push(mse.sqrt());
        }
        rows.push((m.name().to_string(), cells));
    }
    (labels, rows)
}

/// Tables 4–6: alarm-method accuracy for a set of spike thresholds.
/// Returns (per-method rows of accuracies, spike-% row).
pub fn spike_tables(
    scale: &ExperimentScale,
    thresholds: &[SpikeThreshold],
) -> (Vec<(String, Vec<f64>)>, Vec<f64>) {
    let day = scale.steps_per_day;
    let steps = 8 * day; // 7 days history + 1 day forecast
    let gen = TraceGenerator::new(GeneratorConfig::default(), scale.seed ^ 0x46);
    let traces: Vec<Vec<Vec<f64>>> = (0..scale.clusters)
        .map(|c| {
            (0..scale.vms_per_cluster)
                .map(|v| gen.generate_vm_in_cluster(c, v, steps).cpu_ready_series())
                .collect()
        })
        .collect();

    let methods = standard_methods();
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    let mut pct_row = vec![0.0f64; thresholds.len()];
    let mut pct_counts = vec![0usize; thresholds.len()];

    for (mi, m) in methods.iter().enumerate() {
        let mut cells = Vec::with_capacity(thresholds.len());
        for (ti, &thr) in thresholds.iter().enumerate() {
            let mut accs = Vec::new();
            for cluster in &traces {
                for (vi, series) in cluster.iter().enumerate() {
                    let split = steps - day;
                    let hist = &series[..split];
                    let future = &series[split..];
                    let pool_vecs: Vec<&[f64]> = cluster
                        .iter()
                        .enumerate()
                        .filter(|(vj, _)| *vj != vi)
                        .map(|(_, s)| &s[..split])
                        .collect();
                    let (acc, pct) =
                        alarm_forecast_accuracy(m.as_ref(), hist, &pool_vecs, future, thr);
                    accs.push(acc);
                    if mi == 0 {
                        pct_row[ti] += pct;
                        pct_counts[ti] += 1;
                    }
                }
            }
            cells.push(accs.iter().sum::<f64>() / accs.len().max(1) as f64);
        }
        rows.push((m.name().to_string(), cells));
    }
    for (p, c) in pct_row.iter_mut().zip(&pct_counts) {
        *p /= (*c).max(1) as f64;
    }
    (rows, pct_row)
}

/// The §7 method set over a fleet: returns one [`FleetEvaluation`] per
/// embedding method (PRONTO, SP, FD, PM) — the Figure 6/7 inputs.
pub fn figure67_fleets(scale: &ExperimentScale, eval_cfg: &EvalConfig) -> Vec<FleetEvaluation> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), scale.seed ^ 0x67);
    let traces: Vec<VmTrace> = (0..scale.fleet)
        .map(|v| gen.generate_vm_in_cluster(v / 16, v, scale.fleet_steps))
        .collect();
    let d = traces[0].dim();

    let mut fleets = vec![
        FleetEvaluation::new("PRONTO"),
        FleetEvaluation::new("SP"),
        FleetEvaluation::new("FD"),
        FleetEvaluation::new("PM"),
    ];
    for (vi, tr) in traces.iter().enumerate() {
        fleets[0].push(evaluate_method(
            FpcaEdge::new(d, FpcaEdgeConfig::default()),
            tr,
            eval_cfg,
        ));
        fleets[1].push(evaluate_method(
            Spirit::new(d, SpiritConfig::default()),
            tr,
            eval_cfg,
        ));
        fleets[2].push(evaluate_method(FrequentDirections::new(d, 4), tr, eval_cfg));
        fleets[3].push(evaluate_method(
            BlockPowerMethod::new(d, 4, d, scale.seed ^ vi as u64),
            tr,
            eval_cfg,
        ));
    }
    fleets
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scale() -> ExperimentScale {
        ExperimentScale {
            vms_per_cluster: 2,
            clusters: 1,
            steps_per_day: 48,
            history_days: 15,
            fleet: 2,
            fleet_steps: 600,
            seed: 1,
        }
    }

    #[test]
    fn daily_medians_shape() {
        let gen = TraceGenerator::new(GeneratorConfig::default(), 1);
        let tr = gen.generate_vm(0, 480);
        let med = daily_medians(&tr, 48);
        assert_eq!(med.len(), 10);
        assert!(med.iter().all(|m| m.is_finite() && *m >= 0.0));
    }

    #[test]
    fn table1_produces_all_cells() {
        let rows = table1_rmse(&tiny_scale());
        assert_eq!(rows.len(), 4);
        for (name, cells) in rows {
            for c in cells {
                assert!(c.is_finite() && c >= 0.0, "{name}: {c}");
            }
        }
    }

    #[test]
    fn table3_shapes() {
        let (labels, rows) = table3_windows(&tiny_scale());
        assert_eq!(labels.len(), 7);
        assert_eq!(rows.len(), 4);
        for (_, cells) in &rows {
            assert_eq!(cells.len(), 7);
        }
    }

    #[test]
    fn spike_tables_accuracy_in_unit_range() {
        let (rows, pct) = spike_tables(
            &tiny_scale(),
            &[SpikeThreshold::Fixed(500.0), SpikeThreshold::Fixed(1000.0)],
        );
        for (name, cells) in &rows {
            for &c in cells {
                assert!((0.0..=1.0).contains(&c), "{name}: {c}");
            }
        }
        assert!(pct[0] >= pct[1], "spike % must fall with threshold: {pct:?}");
    }

    #[test]
    fn figure67_fleet_coverage() {
        let fleets = figure67_fleets(&tiny_scale(), &EvalConfig::default());
        assert_eq!(fleets.len(), 4);
        for f in &fleets {
            assert_eq!(f.nodes.len(), 2);
        }
    }
}
